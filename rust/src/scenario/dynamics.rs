//! The [`ChannelDynamics`] seam: what advances a link's condition.
//!
//! The seed simulator drove every link with one hard-coded 3-state Markov
//! chain. This module makes the per-link dynamics pluggable:
//!
//! - [`ChannelDynamics::Markov`] keeps the classic chain, now parameterized
//!   by [`crate::channels::FadingParams`] (the Table-1 constants are the
//!   `Default`, bit-for-bit the frozen `step_round` oracle's RNG stream);
//! - [`ChannelDynamics::Trace`] replays a precomputed
//!   `(bandwidth multiplier, loss probability)` trace — loaded from CSV or
//!   produced by the synthetic generators below (diurnal sinusoid,
//!   congestion bursts, Gilbert–Elliott drive-test).
//!
//! Traces are generated **once** per scenario zone from a dedicated forked
//! RNG and shared across links via `Arc`, so replay is deterministic per
//! seed and O(1) per link; each link walks the shared trace from its own
//! phase offset (decorrelating devices without extra memory). The contract
//! every dynamics source honors (property-tested in `tests/properties.rs`):
//! bandwidth multipliers lie in `(0, 1]`, loss probabilities in `[0, 1)`.

use std::sync::Arc;

use crate::util::Rng;

/// One sample of a link-condition trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Bandwidth multiplier in `(0, 1]` (1 = the technology's nominal rate).
    pub bw: f64,
    /// Whole-transfer erasure probability in `[0, 1)`.
    pub loss: f64,
}

/// Validate the dynamics contract over a candidate trace.
pub fn validate_points(points: &[TracePoint]) -> Result<(), String> {
    if points.is_empty() {
        return Err("trace must have at least one point".into());
    }
    for (i, p) in points.iter().enumerate() {
        if !(p.bw > 0.0 && p.bw <= 1.0) {
            return Err(format!("trace point {i}: bw multiplier {} not in (0, 1]", p.bw));
        }
        if !(0.0..1.0).contains(&p.loss) {
            return Err(format!("trace point {i}: loss {} not in [0, 1)", p.loss));
        }
    }
    Ok(())
}

/// A cursor over a shared, immutable trace: the per-link replay state.
#[derive(Clone, Debug)]
pub struct TraceReplay {
    points: Arc<[TracePoint]>,
    cursor: usize,
}

impl TraceReplay {
    /// Replay `points` starting at `offset` (wrapped). Panics on an empty
    /// trace — construction paths validate first.
    pub fn new(points: Arc<[TracePoint]>, offset: usize) -> Self {
        assert!(!points.is_empty(), "trace replay over an empty trace");
        let cursor = offset % points.len();
        TraceReplay { points, cursor }
    }

    /// Advance one tick (wrapping replay).
    pub fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.points.len();
    }

    /// Current bandwidth multiplier.
    pub fn bw(&self) -> f64 {
        self.points[self.cursor].bw
    }

    /// Current loss probability.
    pub fn loss(&self) -> f64 {
        self.points[self.cursor].loss
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn cursor(&self) -> usize {
        self.cursor
    }
}

/// What advances one [`crate::channels::Link`]'s condition each
/// round/tick — the scenario subsystem's seam into the channel simulator.
#[derive(Clone, Debug)]
pub enum ChannelDynamics {
    /// The 3-state Markov fading chain over the link's `FadingParams` —
    /// the default, and with default params **bit-for-bit** the frozen
    /// oracle's stream (one `choice_weighted` draw per step).
    Markov,
    /// Replay a precomputed condition trace; the link's fading state and
    /// RNG stream are left untouched.
    Trace(TraceReplay),
}

/// Parse a CSV trace: one point per non-empty, non-`#` line, either
/// `bw_multiplier` or `bw_multiplier,loss_prob`.
pub fn trace_from_csv(text: &str) -> Result<Arc<[TracePoint]>, String> {
    let mut points = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut cols = line.split(',').map(str::trim);
        let bw: f64 = cols
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|e| format!("trace line {}: bad bw: {e}", lineno + 1))?;
        let loss: f64 = match cols.next() {
            Some(c) if !c.is_empty() => c
                .parse()
                .map_err(|e| format!("trace line {}: bad loss: {e}", lineno + 1))?,
            _ => 0.0,
        };
        points.push(TracePoint { bw, loss });
    }
    validate_points(&points)?;
    Ok(points.into())
}

/// Diurnal sinusoid: bandwidth swings between `floor` and 1.0 over
/// `period` ticks (the classic day/night cellular load curve). Lossless —
/// congestion shapes rate, not erasure. Fully deterministic. The generated
/// length is rounded up to a whole number of periods so the wrapping
/// replay is phase-continuous (no mid-cycle jump at the buffer boundary).
pub fn diurnal_trace(len: usize, period: usize, floor: f64) -> Arc<[TracePoint]> {
    assert!(len > 0 && period > 0);
    assert!(floor > 0.0 && floor <= 1.0, "diurnal floor {floor} not in (0, 1]");
    let len = len.div_ceil(period) * period;
    (0..len)
        .map(|i| {
            let phase = (i % period) as f64 / period as f64;
            let s = 0.5 * (1.0 + (std::f64::consts::TAU * phase).sin());
            TracePoint { bw: (floor + (1.0 - floor) * s).min(1.0), loss: 0.0 }
        })
        .collect()
}

/// Congestion bursts: a two-state chain (clear / congested) with geometric
/// dwell times; congested ticks run at `depth` bandwidth with `burst_loss`
/// erasure (cell overload drops whole transfers).
pub fn congestion_burst_trace(
    len: usize,
    rng: &mut Rng,
    enter: f64,
    exit: f64,
    depth: f64,
    burst_loss: f64,
) -> Arc<[TracePoint]> {
    assert!(len > 0);
    assert!((0.0..1.0).contains(&enter) && (0.0..=1.0).contains(&exit));
    assert!(depth > 0.0 && depth <= 1.0, "burst depth {depth} not in (0, 1]");
    assert!((0.0..1.0).contains(&burst_loss));
    let mut congested = false;
    (0..len)
        .map(|_| {
            let u = rng.uniform();
            if congested {
                if u < exit {
                    congested = false;
                }
            } else if u < enter {
                congested = true;
            }
            if congested {
                TracePoint { bw: depth, loss: burst_loss }
            } else {
                TracePoint { bw: 1.0, loss: 0.0 }
            }
        })
        .collect()
}

/// Gilbert–Elliott drive-test: the standard two-state (Good/Bad) burst-loss
/// channel model; Bad ticks run at `bad_bw` bandwidth with `bad_loss`
/// erasure — the shape of a vehicular trace through coverage holes.
pub fn gilbert_elliott_trace(
    len: usize,
    rng: &mut Rng,
    p_gb: f64,
    p_bg: f64,
    bad_bw: f64,
    bad_loss: f64,
) -> Arc<[TracePoint]> {
    assert!(len > 0);
    assert!((0.0..1.0).contains(&p_gb) && (0.0..=1.0).contains(&p_bg));
    assert!(bad_bw > 0.0 && bad_bw <= 1.0, "bad_bw {bad_bw} not in (0, 1]");
    assert!((0.0..1.0).contains(&bad_loss));
    let mut bad = false;
    (0..len)
        .map(|_| {
            let u = rng.uniform();
            if bad {
                if u < p_bg {
                    bad = false;
                }
            } else if u < p_gb {
                bad = true;
            }
            if bad {
                TracePoint { bw: bad_bw, loss: bad_loss }
            } else {
                TracePoint { bw: 1.0, loss: 0.0 }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_is_deterministic_and_bounded() {
        let a = diurnal_trace(256, 64, 0.25);
        let b = diurnal_trace(256, 64, 0.25);
        assert_eq!(&a[..], &b[..]);
        validate_points(&a).unwrap();
        // It actually swings: max near 1, min near the floor.
        let max = a.iter().map(|p| p.bw).fold(0.0, f64::max);
        let min = a.iter().map(|p| p.bw).fold(1.0, f64::min);
        assert!(max > 0.9, "max={max}");
        assert!(min < 0.35, "min={min}");
    }

    #[test]
    fn synthetic_traces_deterministic_per_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = congestion_burst_trace(512, &mut r1, 0.1, 0.3, 0.2, 0.25);
        let b = congestion_burst_trace(512, &mut r2, 0.1, 0.3, 0.2, 0.25);
        assert_eq!(&a[..], &b[..]);
        validate_points(&a).unwrap();
        let mut r3 = Rng::new(9);
        let c = gilbert_elliott_trace(512, &mut r3, 0.08, 0.4, 0.1, 0.35);
        validate_points(&c).unwrap();
        assert!(c.iter().any(|p| p.bw < 1.0), "GE trace never entered Bad");
    }

    #[test]
    fn replay_wraps_and_offsets() {
        let pts = diurnal_trace(8, 8, 0.5);
        let mut tr = TraceReplay::new(pts.clone(), 6);
        assert_eq!(tr.cursor(), 6);
        tr.advance();
        tr.advance();
        assert_eq!(tr.cursor(), 0, "replay wraps");
        assert_eq!(tr.bw(), pts[0].bw);
        assert_eq!(tr.len(), 8);
    }

    #[test]
    fn csv_parses_and_validates() {
        let pts = trace_from_csv("# drive test\n1.0\n0.5, 0.1\n0.25,0.3\n").unwrap();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[1], TracePoint { bw: 0.5, loss: 0.1 });
        assert!(trace_from_csv("").is_err());
        assert!(trace_from_csv("1.5").is_err(), "bw > 1 rejected");
        assert!(trace_from_csv("0.5, 1.0").is_err(), "loss = 1 rejected");
        assert!(trace_from_csv("0.0").is_err(), "bw = 0 rejected");
    }
}
