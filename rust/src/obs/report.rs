//! The `lgc report <trace.jsonl>` drill-down: parse a recorded trace back
//! into [`TraceRec`]s and post-process it into per-channel utilization
//! histograms, per-zone backhaul occupancy, a straggler top-k, the
//! round-time attribution table, and a Chrome trace-event export that
//! loads in `chrome://tracing` / Perfetto.
//!
//! The parser is a deliberately minimal flat-object JSON reader matched to
//! the recorder's fixed serialization (string and number values only, no
//! nesting, no escapes) — the vendored-only rule means no serde, and the
//! schema validator in `python/trace_check.py` guards the format from the
//! other side.

use std::fmt::Write as _;

use crate::metrics::{histogram, percentile};

/// One parsed trace record. Unset integer keys are `-1`, unset floats NaN.
#[derive(Clone, Debug)]
pub struct TraceRec {
    pub t: f64,
    pub kind: String,
    pub round: i64,
    pub client: i64,
    pub zone: i64,
    pub layer: i64,
    pub channel: i64,
    pub dur: f64,
    pub bytes: i64,
    pub compute: f64,
    pub uplink: f64,
    pub backhaul: f64,
    pub downlink: f64,
    pub wait: f64,
    pub bound: String,
    pub crit_client: i64,
    pub crit_channel: i64,
}

impl Default for TraceRec {
    fn default() -> Self {
        TraceRec {
            t: f64::NAN,
            kind: String::new(),
            round: -1,
            client: -1,
            zone: -1,
            layer: -1,
            channel: -1,
            dur: f64::NAN,
            bytes: -1,
            compute: f64::NAN,
            uplink: f64::NAN,
            backhaul: f64::NAN,
            downlink: f64::NAN,
            wait: f64::NAN,
            bound: String::new(),
            crit_client: -1,
            crit_channel: -1,
        }
    }
}

/// Parse one JSONL line of the recorder's flat-object format.
pub fn parse_line(line: &str) -> Result<TraceRec, String> {
    let s = line.trim();
    let body = s
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .ok_or_else(|| format!("not a JSON object: {s}"))?;
    let mut rec = TraceRec::default();
    let mut rest = body;
    while !rest.is_empty() {
        rest = rest.trim_start_matches([',', ' ']);
        if rest.is_empty() {
            break;
        }
        let rest2 = rest
            .strip_prefix('"')
            .ok_or_else(|| format!("expected key quote in: {rest}"))?;
        let kq = rest2.find('"').ok_or_else(|| format!("unterminated key in: {rest}"))?;
        let key = &rest2[..kq];
        let after = rest2[kq + 1..]
            .strip_prefix(':')
            .ok_or_else(|| format!("expected `:` after key {key}"))?;
        let (value, tail) = if let Some(sv) = after.strip_prefix('"') {
            let vq = sv.find('"').ok_or_else(|| format!("unterminated value for {key}"))?;
            (Val::Str(&sv[..vq]), &sv[vq + 1..])
        } else {
            let end = after.find(',').unwrap_or(after.len());
            let raw = after[..end].trim();
            let num = raw
                .parse::<f64>()
                .map_err(|_| format!("bad number `{raw}` for key {key}"))?;
            (Val::Num(num), &after[end..])
        };
        rec.set(key, value)?;
        rest = tail;
    }
    if rec.kind.is_empty() || !rec.t.is_finite() {
        return Err(format!("record missing t/kind: {s}"));
    }
    Ok(rec)
}

enum Val<'a> {
    Str(&'a str),
    Num(f64),
}

impl TraceRec {
    fn set(&mut self, key: &str, value: Val) -> Result<(), String> {
        let num = |v: &Val| match v {
            Val::Num(n) => Ok(*n),
            Val::Str(_) => Err(format!("key {key} expects a number")),
        };
        match key {
            "t" => self.t = num(&value)?,
            "kind" => match value {
                Val::Str(s) => self.kind = s.to_string(),
                Val::Num(_) => return Err("kind expects a string".into()),
            },
            "bound" => match value {
                Val::Str(s) => self.bound = s.to_string(),
                Val::Num(_) => return Err("bound expects a string".into()),
            },
            "round" => self.round = num(&value)? as i64,
            "client" => self.client = num(&value)? as i64,
            "zone" => self.zone = num(&value)? as i64,
            "layer" => self.layer = num(&value)? as i64,
            "channel" => self.channel = num(&value)? as i64,
            "bytes" => self.bytes = num(&value)? as i64,
            "dur" => self.dur = num(&value)?,
            "compute" => self.compute = num(&value)?,
            "uplink" => self.uplink = num(&value)?,
            "backhaul" => self.backhaul = num(&value)?,
            "downlink" => self.downlink = num(&value)?,
            "wait" => self.wait = num(&value)?,
            "crit_client" => self.crit_client = num(&value)? as i64,
            "crit_channel" => self.crit_channel = num(&value)? as i64,
            other => return Err(format!("unknown trace key `{other}`")),
        }
        Ok(())
    }
}

/// Parse a whole JSONL buffer (empty lines skipped).
pub fn parse(buf: &str) -> Result<Vec<TraceRec>, String> {
    let mut out = Vec::new();
    for (i, line) in buf.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_line(line).map_err(|e| format!("line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// Load + parse a trace file.
pub fn load(path: &str) -> Result<Vec<TraceRec>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    parse(&text)
}

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    format!("{:#<n$}{:.<rest$}", "", "", n = n, rest = width - n)
}

/// Render the full drill-down report.
pub fn render(trace: &[TraceRec], topk: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "trace records: {}", trace.len());
    let span = trace.last().map(|r| r.t).unwrap_or(0.0) - trace.first().map(|r| r.t).unwrap_or(0.0);
    let _ = writeln!(out, "sim span: {span:.3} s");

    // -- round-time attribution ---------------------------------------
    let rounds: Vec<&TraceRec> = trace.iter().filter(|r| r.kind == "round").collect();
    let _ = writeln!(out, "\n== round-time attribution ==");
    if rounds.is_empty() {
        let _ = writeln!(out, "(no round records in trace)");
    } else {
        let total: f64 = rounds.iter().map(|r| r.dur.max(0.0)).sum();
        let comp = |f: fn(&TraceRec) -> f64| -> f64 {
            rounds.iter().map(|r| { let v = f(r); if v.is_finite() { v } else { 0.0 } }).sum()
        };
        let parts = [
            ("compute", comp(|r| r.compute)),
            ("uplink", comp(|r| r.uplink)),
            ("backhaul", comp(|r| r.backhaul)),
            ("downlink", comp(|r| r.downlink)),
            ("wait", comp(|r| r.wait)),
        ];
        let named: f64 = parts.iter().map(|(_, v)| v).sum();
        for (name, v) in parts {
            let pct = if total > 0.0 { 100.0 * v / total } else { 0.0 };
            let bound = rounds.iter().filter(|r| r.bound == name).count();
            let _ = writeln!(
                out,
                "{name:<9} {v:>10.3} s  {pct:>5.1}%  |{}|  bound in {bound} rounds",
                bar(pct / 100.0, 24)
            );
        }
        let cov = if total > 0.0 { 100.0 * named / total } else { 100.0 };
        let _ = writeln!(out, "attributed: {cov:.2}% of {total:.3} s over {} rounds", rounds.len());
        // Slowest rounds, with their dominant component.
        let mut slow: Vec<&&TraceRec> = rounds.iter().collect();
        slow.sort_by(|a, b| b.dur.total_cmp(&a.dur).then(a.round.cmp(&b.round)));
        let _ = writeln!(out, "slowest rounds:");
        for r in slow.iter().take(topk.min(5)) {
            let _ = writeln!(
                out,
                "  round {:>4}  {:>8.3} s  bound_by {:<8}  crit_client {}  crit_channel {}",
                r.round, r.dur, r.bound, r.crit_client, r.crit_channel
            );
        }
    }

    // -- channel utilization ------------------------------------------
    let _ = writeln!(out, "\n== channel utilization ==");
    let max_ch = trace.iter().map(|r| r.channel).max().unwrap_or(-1);
    if max_ch < 0 {
        let _ = writeln!(out, "(no per-channel records in trace)");
    }
    for ch in 0..=max_ch.max(-1) {
        for (label, kind) in [("uplink", "uplink_arrive"), ("downlink", "downlink_arrive")] {
            let mut durs: Vec<f64> = trace
                .iter()
                .filter(|r| r.channel == ch && r.kind == kind && r.dur.is_finite())
                .map(|r| r.dur)
                .collect();
            if durs.is_empty() {
                continue;
            }
            let busy: f64 = durs.iter().sum();
            let util = if span > 0.0 { 100.0 * busy / span } else { 0.0 };
            let p95 = percentile(&mut durs, 95.0);
            let _ = writeln!(
                out,
                "ch{ch} {label:<8} {:>6} transfers  busy {busy:>9.3} s ({util:>5.1}% of span)  p95 {p95:.4} s",
                durs.len()
            );
            let (counts, lo, hi) = histogram(&durs, 8);
            let peak = counts.iter().copied().max().unwrap_or(1).max(1);
            for (b, &c) in counts.iter().enumerate() {
                let x0 = lo + (hi - lo) * b as f64 / counts.len() as f64;
                let x1 = lo + (hi - lo) * (b + 1) as f64 / counts.len() as f64;
                let _ = writeln!(
                    out,
                    "    [{x0:>8.4},{x1:>8.4}) {:<24} {c}",
                    bar(c as f64 / peak as f64, 24)
                );
            }
        }
    }

    // -- backhaul occupancy -------------------------------------------
    let _ = writeln!(out, "\n== backhaul occupancy (per zone) ==");
    // Bytes ride the enqueue record, the transit span rides the arrival —
    // fold both kinds into the per-zone row.
    let max_zone = trace
        .iter()
        .filter(|r| r.kind == "backhaul_arrive" || r.kind == "backhaul_enqueue")
        .map(|r| r.zone)
        .max()
        .unwrap_or(-1);
    if max_zone < 0 {
        let _ = writeln!(out, "(no backhaul records in trace)");
    }
    for z in 0..=max_zone.max(-1) {
        let frames: Vec<&TraceRec> = trace
            .iter()
            .filter(|r| r.kind == "backhaul_arrive" && r.zone == z)
            .collect();
        let bytes: i64 = trace
            .iter()
            .filter(|r| {
                (r.kind == "backhaul_enqueue" || r.kind == "backhaul_arrive") && r.zone == z
            })
            .map(|r| r.bytes.max(0))
            .sum();
        if frames.is_empty() && bytes == 0 {
            continue;
        }
        let busy: f64 = frames.iter().map(|r| if r.dur.is_finite() { r.dur } else { 0.0 }).sum();
        let occ = if span > 0.0 { 100.0 * busy / span } else { 0.0 };
        let _ = writeln!(
            out,
            "zone {z:<3} {:>6} frames  {bytes:>12} B  busy {busy:>9.3} s  |{}| {occ:>5.1}%",
            frames.len(),
            bar(occ / 100.0, 24)
        );
    }

    // -- straggler top-k ----------------------------------------------
    let _ = writeln!(out, "\n== straggler top-{topk} (critical-path clients) ==");
    let mut per_client: Vec<(i64, usize, f64)> = Vec::new();
    for r in &rounds {
        if r.crit_client < 0 {
            continue;
        }
        match per_client.iter_mut().find(|(c, _, _)| *c == r.crit_client) {
            Some(e) => {
                e.1 += 1;
                e.2 += r.dur.max(0.0);
            }
            None => per_client.push((r.crit_client, 1, r.dur.max(0.0))),
        }
    }
    per_client.sort_by(|a, b| b.1.cmp(&a.1).then(b.2.total_cmp(&a.2)).then(a.0.cmp(&b.0)));
    if per_client.is_empty() {
        let _ = writeln!(out, "(no critical-path clients recorded)");
    }
    for (client, n, time) in per_client.iter().take(topk) {
        let _ = writeln!(
            out,
            "client {client:<6} critical in {n:>4} rounds  {time:>9.3} s of round time"
        );
    }
    out
}

/// Serialize the trace as a Chrome trace-event JSON document
/// (`chrome://tracing` / Perfetto "JSON" format): span records become
/// complete (`ph:"X"`) events starting at `t - dur`, points become
/// instants (`ph:"i"`). `pid` maps the zone, `tid` the client.
pub fn chrome_export(trace: &[TraceRec]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for r in trace {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let pid = r.zone.max(0);
        let tid = r.client.max(0);
        if r.dur.is_finite() && r.dur > 0.0 {
            let ts = (r.t - r.dur) * 1e6;
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{:.3},\
                 \"pid\":{pid},\"tid\":{tid}}}",
                r.kind,
                r.dur * 1e6
            );
        } else {
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\
                 \"pid\":{pid},\"tid\":{tid}}}",
                r.kind,
                r.t * 1e6
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_recorder_output() {
        let mut rec = crate::obs::Recorder::to_buffer();
        rec.push(
            crate::obs::Ev::new("uplink_arrive", 1.5)
                .round(2)
                .client(7)
                .layer(1)
                .channel(0)
                .dur(0.25)
                .bytes(4096),
        );
        let mut a = crate::obs::Attribution::none();
        a.compute = 1.0;
        a.uplink = 0.5;
        a.crit_client = 7;
        a.crit_channel = 0;
        a.finalize(1.5);
        rec.push_round(1.5, 2, 1.5, &a);
        let recs = parse(rec.buffer()).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].kind, "uplink_arrive");
        assert_eq!(recs[0].client, 7);
        assert_eq!(recs[0].bytes, 4096);
        assert!((recs[0].dur - 0.25).abs() < 1e-12);
        assert_eq!(recs[1].kind, "round");
        assert_eq!(recs[1].bound, "compute");
        assert!((recs[1].compute + recs[1].uplink + recs[1].wait - 1.5).abs() < 1e-9);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse_line("not json").is_err());
        assert!(parse_line("{\"t\":1.0}").is_err(), "missing kind");
        assert!(parse_line("{\"t\":1.0,\"kind\":\"x\",\"mystery\":3}").is_err());
    }

    #[test]
    fn report_names_all_sections() {
        let mut rec = crate::obs::Recorder::to_buffer();
        rec.push(crate::obs::Ev::new("uplink_arrive", 1.0).client(0).channel(0).dur(0.5));
        rec.push(crate::obs::Ev::new("backhaul_arrive", 2.0).zone(0).dur(0.25).bytes(100));
        let mut a = crate::obs::Attribution::none();
        a.uplink = 2.0;
        a.crit_client = 0;
        a.finalize(2.0);
        rec.push_round(2.0, 0, 2.0, &a);
        let recs = parse(rec.buffer()).unwrap();
        let text = render(&recs, 5);
        for section in [
            "round-time attribution",
            "channel utilization",
            "backhaul occupancy",
            "straggler top-5",
        ] {
            assert!(text.contains(section), "missing {section} in:\n{text}");
        }
        assert!(text.contains("attributed: 100.00%"), "{text}");
    }

    #[test]
    fn chrome_export_is_structurally_sound() {
        let mut rec = crate::obs::Recorder::to_buffer();
        rec.push(crate::obs::Ev::new("uplink_arrive", 1.0).client(3).zone(1).channel(0).dur(0.5));
        rec.push(crate::obs::Ev::new("fading_tick", 2.0));
        let recs = parse(rec.buffer()).unwrap();
        let text = chrome_export(&recs);
        assert!(text.starts_with("{\"traceEvents\":[\n"));
        assert!(text.trim_end().ends_with("]}"));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
        assert!(text.contains("\"ts\":500000.000")); // 1.0 - 0.5 → µs
    }
}
