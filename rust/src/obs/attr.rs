//! Round-time attribution: decompose one round's simulated wall time into
//! named components along the critical path.
//!
//! The engines fill an [`Attribution`] per record with whatever they can
//! measure exactly and call [`Attribution::finalize`], which closes the
//! books: components are clamped non-negative, rescaled if they overshoot
//! (an async window can start a contribution before the window opens), and
//! the residual lands in `wait` — so the five components **always** sum to
//! `round_time_s` within float tolerance, and 100% of every round's time is
//! attributed to a named component.
//!
//! - **Barrier engines**: the critical device is the argmax of per-device
//!   finish walls; `compute` is its local-step time, `uplink` the rest of
//!   its wall, and the backhaul/downlink extensions of the round (edge
//!   flush arrivals, layered broadcast + sync confirms) are exact deltas
//!   beyond the access wall. `wait` is zero by construction.
//! - **Async engines**: a record covers one aggregation window; the
//!   critical contribution is the one with the longest compute+uplink
//!   duration, and `wait` absorbs the server-side pacing (buffer fill,
//!   downlink overlap) the window spent outside that path.

/// Per-round (or per-aggregation-window) time attribution.
#[derive(Clone, Copy, Debug)]
pub struct Attribution {
    /// Local-step compute time of the critical-path client (s).
    pub compute: f64,
    /// Uplink transfer time of the critical-path client (s).
    pub uplink: f64,
    /// Edge→cloud backhaul time extending the round beyond access (s).
    pub backhaul: f64,
    /// Model broadcast + sync-confirm time extending the round (s).
    pub downlink: f64,
    /// Residual barrier/buffer wait (s); filled by [`Attribution::finalize`].
    pub wait: f64,
    /// The critical-path client id (`-1` when no client participated).
    pub crit_client: i64,
    /// The slowest uplink channel of the critical-path client (`-1` none).
    pub crit_channel: i64,
}

impl Default for Attribution {
    fn default() -> Self {
        Attribution::none()
    }
}

impl Attribution {
    /// The empty attribution (all components zero, no critical client).
    pub fn none() -> Self {
        Attribution {
            compute: 0.0,
            uplink: 0.0,
            backhaul: 0.0,
            downlink: 0.0,
            wait: 0.0,
            crit_client: -1,
            crit_channel: -1,
        }
    }

    /// Close the books against the recorded `round_time_s`: clamp components
    /// to `[0, ∞)`, scale down proportionally if they exceed the round time,
    /// and assign the residual to `wait` so the components sum exactly.
    pub fn finalize(&mut self, round_time_s: f64) {
        let rt = if round_time_s.is_finite() { round_time_s.max(0.0) } else { 0.0 };
        let clamp = |x: f64| if x.is_finite() { x.max(0.0) } else { 0.0 };
        self.compute = clamp(self.compute);
        self.uplink = clamp(self.uplink);
        self.backhaul = clamp(self.backhaul);
        self.downlink = clamp(self.downlink);
        let named = self.compute + self.uplink + self.backhaul + self.downlink;
        if named > rt && named > 0.0 {
            let scale = rt / named;
            self.compute *= scale;
            self.uplink *= scale;
            self.backhaul *= scale;
            self.downlink *= scale;
        }
        self.wait =
            (rt - (self.compute + self.uplink + self.backhaul + self.downlink)).max(0.0);
    }

    /// The dominant component's label — the `bound_by` CSV column. Empty
    /// when the round spent no time at all (e.g. the zero-duration record
    /// of a fully-drained run).
    pub fn bound_by(&self) -> &'static str {
        let parts = [
            (self.compute, "compute"),
            (self.uplink, "uplink"),
            (self.backhaul, "backhaul"),
            (self.downlink, "downlink"),
            (self.wait, "wait"),
        ];
        let mut best = 0.0;
        let mut label = "";
        for (v, name) in parts {
            if v > best {
                best = v;
                label = name;
            }
        }
        label
    }

    /// Sum of all five components (equals `round_time_s` after finalize).
    pub fn total(&self) -> f64 {
        self.compute + self.uplink + self.backhaul + self.downlink + self.wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_assigns_residual_to_wait() {
        let mut a = Attribution::none();
        a.compute = 1.0;
        a.uplink = 0.5;
        a.finalize(2.0);
        assert!((a.wait - 0.5).abs() < 1e-12);
        assert!((a.total() - 2.0).abs() < 1e-12);
        assert_eq!(a.bound_by(), "compute");
    }

    #[test]
    fn finalize_rescales_overshoot() {
        let mut a = Attribution::none();
        a.compute = 3.0;
        a.uplink = 1.0;
        a.finalize(2.0);
        assert!((a.total() - 2.0).abs() < 1e-12);
        assert!((a.compute - 1.5).abs() < 1e-12);
        assert!((a.uplink - 0.5).abs() < 1e-12);
        assert_eq!(a.wait, 0.0);
    }

    #[test]
    fn finalize_clamps_garbage() {
        let mut a = Attribution::none();
        a.compute = f64::NAN;
        a.uplink = -1.0;
        a.backhaul = f64::INFINITY;
        a.finalize(1.0);
        assert_eq!(a.compute, 0.0);
        assert_eq!(a.uplink, 0.0);
        assert_eq!(a.backhaul, 0.0);
        assert!((a.wait - 1.0).abs() < 1e-12);
        assert_eq!(a.bound_by(), "wait");
    }

    #[test]
    fn empty_round_has_no_bound() {
        let mut a = Attribution::none();
        a.finalize(0.0);
        assert_eq!(a.bound_by(), "");
        assert_eq!(a.total(), 0.0);
    }
}
