//! Wall-clock scoped phase timers (config key `profile`): how much real
//! time the run spent in each engine phase, accumulated per process and
//! reported as `profile/<phase>_ms` lines and bench-compatible JSON rows.
//!
//! These measure **wall time**, never sim time — they exist to localize
//! host-side hot spots (is the run event-loop-bound or train-bound?) and
//! to land trace-overhead shifts in the bench trajectory. They are
//! deliberately excluded from the JSONL trace, which must stay
//! deterministic across runs.

/// The coarse engine phases the timers distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Everything inside the discrete-event loop (superset of the rest).
    EventLoop,
    /// Device local training steps.
    Train,
    /// Gradient compression + upload encoding.
    Compress,
    /// Server aggregation + model apply.
    Aggregate,
}

pub const PHASES: [Phase; 4] = [Phase::EventLoop, Phase::Train, Phase::Compress, Phase::Aggregate];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::EventLoop => "event_loop",
            Phase::Train => "train",
            Phase::Compress => "compress",
            Phase::Aggregate => "aggregate",
        }
    }

    fn idx(&self) -> usize {
        match self {
            Phase::EventLoop => 0,
            Phase::Train => 1,
            Phase::Compress => 2,
            Phase::Aggregate => 3,
        }
    }
}

/// Accumulated wall-clock nanoseconds per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimers {
    ns: [u64; 4],
}

impl PhaseTimers {
    pub fn add(&mut self, phase: Phase, ns: u64) {
        self.ns[phase.idx()] += ns;
    }

    pub fn ns(&self, phase: Phase) -> u64 {
        self.ns[phase.idx()]
    }

    pub fn ms(&self, phase: Phase) -> f64 {
        self.ns[phase.idx()] as f64 / 1e6
    }

    /// Whether any phase recorded time (i.e. profiling actually ran).
    pub fn any(&self) -> bool {
        self.ns.iter().any(|&n| n > 0)
    }

    /// Fold another accumulator in (per-shard timers merge here).
    pub fn merge(&mut self, other: &PhaseTimers) {
        for (a, b) in self.ns.iter_mut().zip(&other.ns) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_merges_per_phase() {
        let mut t = PhaseTimers::default();
        assert!(!t.any());
        t.add(Phase::Train, 1_500_000);
        t.add(Phase::Train, 500_000);
        t.add(Phase::Aggregate, 1_000_000);
        assert_eq!(t.ns(Phase::Train), 2_000_000);
        assert!((t.ms(Phase::Train) - 2.0).abs() < 1e-12);
        assert_eq!(t.ns(Phase::EventLoop), 0);
        let mut u = PhaseTimers::default();
        u.add(Phase::Train, 1_000_000);
        t.merge(&u);
        assert_eq!(t.ns(Phase::Train), 3_000_000);
        assert!(t.any());
    }

    #[test]
    fn phase_names_are_stable() {
        let names: Vec<&str> = PHASES.iter().map(|p| p.name()).collect();
        assert_eq!(names, ["event_loop", "train", "compress", "aggregate"]);
    }
}
