//! Structured simulation telemetry behind the [`Recorder`] seam.
//!
//! The recorder is **off by default** (config keys `trace` / `trace_file`)
//! and then strictly zero-cost: every emission site guards on
//! [`Recorder::on`], no buffer exists, and all engines stay bit-for-bit on
//! the frozen `step_round` oracle (the golden-trace suite and the
//! steady-state allocation test pin this). Enabled, it appends one JSON
//! object per line (JSONL) to an in-memory buffer in **virtual sim time**,
//! causally keyed by `(round, client, zone, layer, channel, kind)`, and
//! flushes to `trace_file` when the run ends. Because the engines are
//! deterministic and the serialization has a fixed key order, two identical
//! seeded runs emit byte-identical traces.
//!
//! Three consumers sit on top:
//!
//! - [`attr::Attribution`] — in-process round-time attribution (compute /
//!   uplink / backhaul / downlink / wait, plus the critical-path client and
//!   channel), surfaced as the `bound_by` / `crit_client` / `crit_channel`
//!   columns of [`crate::metrics::RoundRecord`].
//! - [`report`] — the `lgc report <trace.jsonl>` drill-down: attribution
//!   tables, channel-utilization histograms, per-zone backhaul occupancy,
//!   straggler top-k, and a Chrome trace-event (Perfetto-loadable) export.
//! - [`phase::PhaseTimers`] — wall-clock scoped phase timers (config key
//!   `profile`), reported as bench-compatible JSON rows.
//!
//! See DESIGN.md §"Observability & trace schema".

pub mod attr;
pub mod phase;
pub mod report;

use std::fmt::Write as _;

pub use attr::Attribution;
pub use phase::{Phase, PhaseTimers};

/// Sentinel for "field not set" in an [`Ev`]; serialized fields with this
/// value are omitted from the JSONL line.
pub const NONE: i64 = -1;

/// One trace record under construction — a tiny `Copy` builder so emission
/// sites read as `rec.push(Ev::new("uplink_arrive", t).client(i).layer(l))`.
/// Unset fields are omitted from the serialized line.
#[derive(Clone, Copy, Debug)]
pub struct Ev {
    pub kind: &'static str,
    pub t: f64,
    pub round: i64,
    pub client: i64,
    pub zone: i64,
    pub layer: i64,
    pub channel: i64,
    /// Span duration in sim seconds ending at `t`; NaN = point event.
    pub dur_s: f64,
    pub bytes: i64,
}

impl Ev {
    pub fn new(kind: &'static str, t: f64) -> Self {
        Ev {
            kind,
            t,
            round: NONE,
            client: NONE,
            zone: NONE,
            layer: NONE,
            channel: NONE,
            dur_s: f64::NAN,
            bytes: NONE,
        }
    }

    pub fn round(mut self, r: usize) -> Self {
        self.round = r as i64;
        self
    }
    pub fn client(mut self, c: usize) -> Self {
        self.client = c as i64;
        self
    }
    pub fn zone(mut self, z: usize) -> Self {
        self.zone = z as i64;
        self
    }
    pub fn layer(mut self, l: usize) -> Self {
        self.layer = l as i64;
        self
    }
    pub fn channel(mut self, ch: usize) -> Self {
        self.channel = ch as i64;
        self
    }
    pub fn dur(mut self, s: f64) -> Self {
        self.dur_s = s;
        self
    }
    pub fn bytes(mut self, b: u64) -> Self {
        self.bytes = b as i64;
        self
    }
}

/// The recorder seam: a no-op by default, a buffered JSONL writer when the
/// config enables tracing, plus the wall-clock phase timers (`profile`).
#[derive(Debug, Default)]
pub struct Recorder {
    enabled: bool,
    profile: bool,
    path: Option<String>,
    buf: String,
    events: u64,
    pub timers: PhaseTimers,
}

impl Recorder {
    /// The zero-cost default: nothing is buffered, nothing is written.
    pub fn disabled() -> Self {
        Recorder::default()
    }

    /// Buffer JSONL in memory without a file destination (tests/benches).
    pub fn to_buffer() -> Self {
        Recorder { enabled: true, ..Recorder::default() }
    }

    /// Buffer JSONL and flush it to `path` when the run ends.
    pub fn to_file(path: &str) -> Self {
        Recorder {
            enabled: true,
            path: Some(path.to_string()),
            ..Recorder::default()
        }
    }

    /// Resolve from the config keys: `trace` is the master switch (the
    /// parser flips it on when `trace_file` names a destination),
    /// `trace_file` the destination (default `trace.jsonl`); `profile`
    /// switches the phase timers on independently.
    pub fn from_cfg(cfg: &crate::config::ExperimentConfig) -> Self {
        let mut rec = if cfg.trace {
            Recorder::to_file(cfg.trace_file.as_deref().unwrap_or("trace.jsonl"))
        } else {
            Recorder::disabled()
        };
        rec.profile = cfg.profile;
        rec
    }

    /// Whether trace emission is live. Every emission site guards on this
    /// so the disabled recorder costs one predictable branch.
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// Whether the wall-clock phase timers are live.
    #[inline]
    pub fn profiling(&self) -> bool {
        self.profile
    }

    /// Force the phase timers on/off (bench harness convenience).
    pub fn set_profiling(&mut self, on: bool) {
        self.profile = on;
    }

    /// Records emitted so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// The buffered JSONL bytes (byte-identical across identical seeded
    /// runs — the trace-determinism contract).
    pub fn buffer(&self) -> &str {
        &self.buf
    }

    /// Append one record. Key order is fixed (`t`, `kind`, then the set
    /// causal keys in declaration order) so serialization is deterministic.
    pub fn push(&mut self, ev: Ev) {
        if !self.enabled {
            return;
        }
        self.events += 1;
        let _ = write!(self.buf, "{{\"t\":{:?},\"kind\":\"{}\"", ev.t, ev.kind);
        if ev.round >= 0 {
            let _ = write!(self.buf, ",\"round\":{}", ev.round);
        }
        if ev.client >= 0 {
            let _ = write!(self.buf, ",\"client\":{}", ev.client);
        }
        if ev.zone >= 0 {
            let _ = write!(self.buf, ",\"zone\":{}", ev.zone);
        }
        if ev.layer >= 0 {
            let _ = write!(self.buf, ",\"layer\":{}", ev.layer);
        }
        if ev.channel >= 0 {
            let _ = write!(self.buf, ",\"channel\":{}", ev.channel);
        }
        if ev.dur_s.is_finite() {
            let _ = write!(self.buf, ",\"dur\":{:?}", ev.dur_s);
        }
        if ev.bytes >= 0 {
            let _ = write!(self.buf, ",\"bytes\":{}", ev.bytes);
        }
        self.buf.push_str("}\n");
    }

    /// Append the per-round attribution record — the one the `lgc report`
    /// attribution table and the attribution-sums property test read back.
    pub fn push_round(&mut self, t: f64, round: usize, round_time_s: f64, a: &Attribution) {
        if !self.enabled {
            return;
        }
        self.events += 1;
        let fin = |x: f64| if x.is_finite() { x } else { 0.0 };
        let _ = write!(
            self.buf,
            "{{\"t\":{:?},\"kind\":\"round\",\"round\":{round},\"dur\":{:?},\
             \"compute\":{:?},\"uplink\":{:?},\"backhaul\":{:?},\"downlink\":{:?},\
             \"wait\":{:?},\"bound\":\"{}\",\"crit_client\":{},\"crit_channel\":{}}}",
            t,
            fin(round_time_s),
            fin(a.compute),
            fin(a.uplink),
            fin(a.backhaul),
            fin(a.downlink),
            fin(a.wait),
            a.bound_by(),
            a.crit_client,
            a.crit_channel,
        );
        self.buf.push('\n');
    }

    /// Start a wall-clock phase measurement (None when profiling is off).
    #[inline]
    pub fn phase_start(&self) -> Option<std::time::Instant> {
        if self.profile {
            Some(std::time::Instant::now())
        } else {
            None
        }
    }

    /// Close a phase measurement opened by [`Recorder::phase_start`].
    #[inline]
    pub fn phase_end(&mut self, phase: Phase, t0: Option<std::time::Instant>) {
        if let Some(t0) = t0 {
            self.timers.add(phase, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Write the buffered trace to `trace_file`, if one was configured.
    /// Returns the destination path when a file was written.
    pub fn flush(&mut self) -> std::io::Result<Option<&str>> {
        match &self.path {
            Some(path) if self.enabled => {
                std::fs::write(path, &self.buf)?;
                Ok(Some(path))
            }
            _ => Ok(None),
        }
    }
}

/// The consolidated end-of-run summary: `lgc train`'s banner and report
/// lines collected behind one render path (`key: value` per line) instead
/// of scattered `println!`s, so greppable lines like `peak_rss_mb:` have a
/// single owner and degrade explicitly (never silently omitted).
#[derive(Debug, Default)]
pub struct Report {
    lines: Vec<(String, String)>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    /// Append one `key: value` line.
    pub fn push(&mut self, key: &str, value: impl std::fmt::Display) {
        self.lines.push((key.to_string(), value.to_string()));
    }

    /// Append a section separator (blank line).
    pub fn gap(&mut self) {
        self.lines.push((String::new(), String::new()));
    }

    /// Append a raw line verbatim (headers like `== name ==`).
    pub fn raw(&mut self, line: impl Into<String>) {
        self.lines.push((String::new(), line.into()));
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.lines {
            if k.is_empty() && v.is_empty() {
                out.push('\n');
            } else if k.is_empty() {
                let _ = writeln!(out, "{v}");
            } else {
                let _ = writeln!(out, "{k}: {v}");
            }
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_emits_nothing() {
        let mut rec = Recorder::disabled();
        rec.push(Ev::new("compute_done", 1.0).client(3));
        rec.push_round(2.0, 0, 2.0, &Attribution::none());
        assert!(!rec.on());
        assert_eq!(rec.events(), 0);
        assert!(rec.buffer().is_empty());
        assert!(rec.flush().unwrap().is_none());
    }

    #[test]
    fn push_serializes_fixed_key_order_and_omits_unset() {
        let mut rec = Recorder::to_buffer();
        rec.push(Ev::new("uplink_arrive", 1.5).round(2).client(7).layer(1).channel(0).dur(0.25));
        rec.push(Ev::new("fading_tick", 2.0));
        let lines: Vec<&str> = rec.buffer().lines().collect();
        assert_eq!(
            lines[0],
            "{\"t\":1.5,\"kind\":\"uplink_arrive\",\"round\":2,\"client\":7,\
             \"layer\":1,\"channel\":0,\"dur\":0.25}"
        );
        assert_eq!(lines[1], "{\"t\":2.0,\"kind\":\"fading_tick\"}");
        assert_eq!(rec.events(), 2);
    }

    #[test]
    fn round_record_carries_attribution() {
        let mut rec = Recorder::to_buffer();
        let mut a = Attribution::none();
        a.compute = 1.0;
        a.uplink = 2.0;
        a.crit_client = 4;
        a.crit_channel = 1;
        a.finalize(3.5);
        rec.push_round(10.0, 7, 3.5, &a);
        let line = rec.buffer().lines().next().unwrap();
        assert!(line.contains("\"kind\":\"round\""), "{line}");
        assert!(line.contains("\"compute\":1.0"), "{line}");
        assert!(line.contains("\"wait\":0.5"), "{line}");
        assert!(line.contains("\"bound\":\"uplink\""), "{line}");
        assert!(line.contains("\"crit_client\":4"), "{line}");
    }

    #[test]
    fn report_renders_one_line_per_kv() {
        let mut r = Report::new();
        r.push("peak_rss_mb", "unavailable");
        r.gap();
        r.push("rounds", 12);
        assert_eq!(r.render(), "peak_rss_mb: unavailable\n\nrounds: 12\n");
    }
}
