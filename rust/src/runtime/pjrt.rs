//! XLA/PJRT-backed runtime (the `pjrt` cargo feature): compiles the AOT HLO
//! artifacts on a CPU PJRT client and executes them.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ModelMeta};
use super::BatchX;

fn to_literal(x: &BatchX, dims: &[i64]) -> Result<xla::Literal> {
    let lit = match x {
        BatchX::F32(v) => xla::Literal::vec1(v),
        BatchX::I32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(dims)?)
}

/// Compiled graphs of one model.
pub struct ModelExecutable {
    pub meta: ModelMeta,
    local: xla::PjRtLoadedExecutable,
    grad: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    x_dims: Vec<i64>,
    batch: usize,
}

impl ModelExecutable {
    /// One local SGD step (Alg. 1 line 6): `(params, x, y, lr) -> (params',
    /// loss)`. `params` is updated in place.
    pub fn local_step(&self, params: &mut Vec<f32>, x: &BatchX, y: &[i32], lr: f32) -> Result<f64> {
        anyhow::ensure!(params.len() == self.meta.params, "params len mismatch");
        let p = xla::Literal::vec1(params.as_slice());
        let xl = to_literal(x, &self.x_dims)?;
        let yl = xla::Literal::vec1(y);
        let lrl = xla::Literal::scalar(lr);
        let result = self.local.execute::<xla::Literal>(&[p, xl, yl, lrl])?[0][0]
            .to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2, "local graph returned {} outputs", outs.len());
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0] as f64;
        let new_params = outs.pop().unwrap().to_vec::<f32>()?;
        *params = new_params;
        Ok(loss)
    }

    /// Raw gradient: `(params, x, y) -> (grads, loss)`.
    pub fn grad(&self, params: &[f32], x: &BatchX, y: &[i32]) -> Result<(Vec<f32>, f64)> {
        let p = xla::Literal::vec1(params);
        let xl = to_literal(x, &self.x_dims)?;
        let yl = xla::Literal::vec1(y);
        let result = self.grad.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2, "grad graph returned {} outputs", outs.len());
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0] as f64;
        let grads = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((grads, loss))
    }

    /// Eval one batch: returns (loss_sum, correct_count) over the batch's
    /// prediction positions.
    pub fn eval_batch(&self, params: &[f32], x: &BatchX, y: &[i32]) -> Result<(f64, f64)> {
        let p = xla::Literal::vec1(params);
        let xl = to_literal(x, &self.x_dims)?;
        let yl = xla::Literal::vec1(y);
        let result = self.eval.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2, "eval graph returned {} outputs", outs.len());
        let correct = outs.pop().unwrap().to_vec::<f32>()?[0] as f64;
        let loss_sum = outs.pop().unwrap().to_vec::<f32>()?[0] as f64;
        Ok((loss_sum, correct))
    }

    pub fn batch(&self) -> usize {
        self.batch
    }
}

/// The LGC encoder artifact (ablation A2): `(u) -> (layers, thr)`.
pub struct CompressExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub d: usize,
    pub n_layers: usize,
}

impl CompressExecutable {
    /// Returns (dense layers `[n_layers * d]` row-major, thresholds).
    pub fn compress(&self, u: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(u.len() == self.d, "expected D={} got {}", self.d, u.len());
        let ul = xla::Literal::vec1(u);
        let result = self.exe.execute::<xla::Literal>(&[ul])?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2);
        let thr = outs.pop().unwrap().to_vec::<f32>()?;
        let layers = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((layers, thr))
    }
}

/// The PJRT runtime: one CPU client + artifact loading.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the manifest in `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(&dir.join("manifest.toml"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Load + compile the three graphs of `model` ("lr" | "cnn" | "rnn").
    pub fn load_model(&self, model: &str) -> Result<ModelExecutable> {
        let meta = self
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model `{model}` not in manifest"))?
            .clone();
        let local = self.compile_file(&format!("{model}_local.hlo.txt"))?;
        let grad = self.compile_file(&format!("{model}_grad.hlo.txt"))?;
        let eval = self.compile_file(&format!("{model}_eval.hlo.txt"))?;
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        Ok(ModelExecutable { meta, local, grad, eval, x_dims, batch: self.manifest.batch })
    }

    /// Load + compile the LGC compress artifact.
    pub fn load_compress(&self) -> Result<CompressExecutable> {
        let d = self.manifest.compress_d;
        let exe = self.compile_file(&format!("lgc_compress_d{d}.hlo.txt"))?;
        Ok(CompressExecutable { exe, d, n_layers: self.manifest.compress_ks.len() })
    }

    /// Load the deterministic initial parameters exported by aot.py.
    pub fn load_init_params(&self, model: &str) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model `{model}` not in manifest"))?;
        let path = self.dir.join(format!("{model}_init.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == meta.params * 4,
            "init file {} has {} bytes, expected {}",
            path.display(),
            bytes.len(),
            meta.params * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}
