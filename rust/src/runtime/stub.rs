//! No-op runtime backend used when the `pjrt` feature is disabled.
//!
//! Mirrors the public API of the XLA-backed [`super::pjrt`] module so that
//! callers (CLI, trainers, examples, benches) compile unchanged; every
//! constructor fails with a clear message pointing at the native path.

use std::path::Path;

use anyhow::{bail, Result};

use super::manifest::{Manifest, ModelMeta};
use super::BatchX;

const NO_PJRT: &str = "this build has no PJRT backend (compiled without the `pjrt` \
     cargo feature) — use the native trainer (`use_runtime = false` / \
     `--use_runtime=false`), or add an `xla` dependency to Cargo.toml and \
     rebuild with `--features pjrt` (DESIGN.md \"Runtime backends\")";

/// Stub of the compiled model graphs. Never constructible: [`Runtime::new`]
/// always fails first, so these methods are unreachable by design.
pub struct ModelExecutable {
    pub meta: ModelMeta,
    batch: usize,
}

impl ModelExecutable {
    pub fn local_step(
        &self,
        _params: &mut Vec<f32>,
        _x: &BatchX,
        _y: &[i32],
        _lr: f32,
    ) -> Result<f64> {
        bail!(NO_PJRT)
    }

    pub fn grad(&self, _params: &[f32], _x: &BatchX, _y: &[i32]) -> Result<(Vec<f32>, f64)> {
        bail!(NO_PJRT)
    }

    pub fn eval_batch(&self, _params: &[f32], _x: &BatchX, _y: &[i32]) -> Result<(f64, f64)> {
        bail!(NO_PJRT)
    }

    pub fn batch(&self) -> usize {
        self.batch
    }
}

/// Stub of the LGC encoder artifact.
pub struct CompressExecutable {
    pub d: usize,
    pub n_layers: usize,
}

impl CompressExecutable {
    pub fn compress(&self, _u: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!(NO_PJRT)
    }
}

/// Stub runtime: construction always fails.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(_dir: &Path) -> Result<Self> {
        bail!(NO_PJRT)
    }

    pub fn platform(&self) -> String {
        "none (pjrt feature disabled)".to_string()
    }

    pub fn load_model(&self, _model: &str) -> Result<ModelExecutable> {
        bail!(NO_PJRT)
    }

    pub fn load_compress(&self) -> Result<CompressExecutable> {
        bail!(NO_PJRT)
    }

    pub fn load_init_params(&self, _model: &str) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_with_guidance() {
        let err = Runtime::new(Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
