//! PJRT runtime seam: load the AOT HLO-text artifacts once, compile them on
//! the CPU PJRT client, and execute them from the round loop.
//!
//! Python never runs here — `make artifacts` produced the `.hlo.txt` files
//! at build time; this module is the only bridge between the Rust
//! coordinator and the L2/L1 compute graphs.
//!
//! The XLA-backed implementation is compiled only with the `pjrt` cargo
//! feature (it needs an `xla` crate in the build environment). The default
//! build ships a stub with the identical public API whose constructors
//! return an error, so every caller — CLI, examples, benches — compiles and
//! runs on the dependency-free native path.

pub mod manifest;

pub use manifest::{Manifest, ModelMeta};

/// Batch input: model x-tensors are f32 (lr/cnn images) or i32 (rnn tokens).
#[derive(Clone, Debug)]
pub enum BatchX {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchX {
    pub fn len(&self) -> usize {
        match self {
            BatchX::F32(v) => v.len(),
            BatchX::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{CompressExecutable, ModelExecutable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{CompressExecutable, ModelExecutable, Runtime};
