//! Parse `artifacts/manifest.toml` (written by `python -m compile.aot`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::toml::Document;

/// Static metadata of one model artifact set.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    /// Flat parameter count P.
    pub params: usize,
    /// x tensor shape, e.g. [64, 784].
    pub x_shape: Vec<usize>,
    /// "f32" | "i32".
    pub x_dtype: String,
}

/// Parsed manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub batch: usize,
    pub img: usize,
    pub nclass: usize,
    pub vocab: usize,
    pub seq: usize,
    pub compress_d: usize,
    pub compress_ks: Vec<usize>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = Document::parse(text).map_err(|e| anyhow!("{e}"))?;
        let top_i = |k: &str| -> Result<usize> {
            doc.get_i64("", k)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest missing top-level `{k}`"))
        };
        let mut models = BTreeMap::new();
        for (name, _) in doc.sections_in_order() {
            if name.is_empty() {
                continue;
            }
            let params = doc
                .get_i64(name, "params")
                .ok_or_else(|| anyhow!("model [{name}] missing params"))?
                as usize;
            let x_shape_str = doc
                .get_str(name, "x_shape")
                .ok_or_else(|| anyhow!("model [{name}] missing x_shape"))?;
            let x_shape: Vec<usize> = x_shape_str
                .split('x')
                .map(|s| s.parse::<usize>().map_err(|e| anyhow!("bad x_shape: {e}")))
                .collect::<Result<_>>()?;
            let x_dtype = doc
                .get_str(name, "x_dtype")
                .ok_or_else(|| anyhow!("model [{name}] missing x_dtype"))?
                .to_string();
            anyhow::ensure!(
                x_dtype == "f32" || x_dtype == "i32",
                "model [{name}] has unsupported x_dtype {x_dtype}"
            );
            models.insert(
                name.to_string(),
                ModelMeta { name: name.to_string(), params, x_shape, x_dtype },
            );
        }
        Ok(Manifest {
            batch: top_i("batch")?,
            img: top_i("img")?,
            nclass: top_i("nclass")?,
            vocab: top_i("vocab")?,
            seq: top_i("seq")?,
            compress_d: top_i("compress_d")?,
            compress_ks: doc
                .get_vec_i64("", "compress_ks")
                .ok_or_else(|| anyhow!("manifest missing compress_ks"))?
                .into_iter()
                .map(|v| v as usize)
                .collect(),
            models,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
batch = 64\nimg = 784\nnclass = 10\nvocab = 64\nseq = 24\ncompress_d = 65536\n\
compress_ks = [655, 2621, 9830]\n\n[lr]\nparams = 7850\nx_shape = \"64x784\"\nx_dtype = \"f32\"\n\n\
[rnn]\nparams = 72128\nx_shape = \"64x25\"\nx_dtype = \"i32\"\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.compress_ks, vec![655, 2621, 9830]);
        assert_eq!(m.models["lr"].params, 7850);
        assert_eq!(m.models["lr"].x_shape, vec![64, 784]);
        assert_eq!(m.models["rnn"].x_dtype, "i32");
        assert_eq!(m.models.len(), 2);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("batch = 64\n").is_err());
        assert!(Manifest::parse(&SAMPLE.replace("params = 7850\n", "")).is_err());
        assert!(Manifest::parse(&SAMPLE.replace("\"f32\"", "\"f64\"")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.toml");
        if !path.exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.models["lr"].params, 7850);
        assert_eq!(m.models["cnn"].params, 206922);
        assert_eq!(m.models["rnn"].params, 72128);
    }
}
