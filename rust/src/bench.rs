//! Bench harness (no `criterion` offline): warmup + timed iterations with
//! mean / p50 / p99 and throughput reporting, and a tiny table printer used
//! by the figure benches to emit paper-style rows.

use std::fmt::Write as _;
use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput given per-iteration work in bytes.
    pub fn gib_per_s(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / (self.mean_ns / 1e9) / (1024.0 * 1024.0 * 1024.0)
    }

    pub fn report(&self, extra: &str) {
        println!(
            "{:<44} {:>10.2} us/iter  p50 {:>9.2}  p99 {:>9.2}  ({} iters){}{}",
            self.name,
            self.mean_us(),
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.iters,
            if extra.is_empty() { "" } else { "  " },
            extra
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[iters / 2],
        p99_ns: samples[(iters * 99 / 100).min(iters - 1)],
        min_ns: samples[0],
    }
}

/// Auto-calibrating variant: picks an iteration count that takes roughly
/// `target_ms` total.
pub fn bench_auto<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // measure one call
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_ms * 1e6 / once_ns).ceil() as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Machine-readable bench records, opt-in via `--json` on the bench argv:
/// each bench collects `{bench, case, value, unit}` rows and writes them to
/// `BENCH_<name>.json` in the working directory. CI diffs these against the
/// committed `BENCH_BASELINE.json` with `python/bench_diff.py` (counts must
/// match exactly, timing/throughput gets a tolerance band; `null` baseline
/// values bless instead of compare).
pub struct JsonSink {
    bench: String,
    records: Vec<(String, f64, String)>,
    enabled: bool,
}

impl JsonSink {
    /// Build from the bench binary's argv (`--json` enables emission).
    pub fn from_args(bench: &str) -> Self {
        JsonSink {
            bench: bench.to_string(),
            records: Vec::new(),
            enabled: std::env::args().any(|a| a == "--json"),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one measurement. `case` should be a stable slash-separated
    /// path (`"overhead/stadium/events_per_s"`); `unit` drives the diff
    /// policy in bench_diff.py (`count`/`bytes` exact, the rest banded).
    pub fn push(&mut self, case: &str, value: f64, unit: &str) {
        self.records.push((case.to_string(), value, unit.to_string()));
    }

    /// The serialized record array (valid JSON; values clamped finite).
    pub fn render(&self) -> String {
        let mut out = String::from("[\n");
        for (i, (case, value, unit)) in self.records.iter().enumerate() {
            let sep = if i + 1 == self.records.len() { "" } else { "," };
            let v = if value.is_finite() { *value } else { 0.0 };
            let _ = writeln!(
                out,
                "  {{\"bench\": \"{}\", \"case\": \"{}\", \"value\": {v:.6}, \"unit\": \"{}\"}}{sep}",
                self.bench, case, unit
            );
        }
        out.push_str("]\n");
        out
    }

    /// Write `BENCH_<bench>.json`; no-op without `--json`.
    pub fn finish(&self) {
        if !self.enabled {
            return;
        }
        let path = format!("BENCH_{}.json", self.bench);
        std::fs::write(&path, self.render()).expect("write bench json");
        println!("wrote {path} ({} records)", self.records.len());
    }
}

/// Simple fixed-width table printer for figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Shared rendering for the figure benches: given one [`RunLog`] per
/// mechanism, print the paper's four panels (loss vs round, accuracy vs
/// round, accuracy under energy budgets, accuracy under money budgets).
pub mod figures {
    use super::Table;
    use crate::metrics::RunLog;

    /// Panels 1+2: loss / accuracy convergence curves, sampled at the
    /// evaluated rounds.
    pub fn print_convergence(logs: &[RunLog]) {
        let mut headers = vec!["round".to_string()];
        for log in logs {
            headers.push(format!("{} loss", log.name));
            headers.push(format!("{} acc", log.name));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut table = Table::new(&hdr_refs);
        let rounds: Vec<usize> = logs[0]
            .records
            .iter()
            .filter(|r| !r.eval_acc.is_nan())
            .map(|r| r.round)
            .collect();
        for &round in &rounds {
            let mut cells = vec![round.to_string()];
            for log in logs {
                match log.records.iter().find(|r| r.round == round && !r.eval_acc.is_nan()) {
                    Some(r) => {
                        cells.push(format!("{:.4}", r.eval_loss));
                        cells.push(format!("{:.4}", r.eval_acc));
                    }
                    None => {
                        cells.push("-".into());
                        cells.push("-".into());
                    }
                }
            }
            table.row(&cells);
        }
        println!("\n-- convergence: eval loss / accuracy vs round --");
        table.print();
    }

    /// Panels 3+4: best accuracy under increasing resource budgets
    /// (`resource`: 0 = energy J, 1 = money).
    pub fn print_budget_panel(logs: &[RunLog], resource: usize, budgets: &[f64], unit: &str) {
        let mut headers = vec![format!("budget ({unit})")];
        for log in logs {
            headers.push(log.name.clone());
        }
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut table = Table::new(&hdr_refs);
        for &b in budgets {
            let mut cells = vec![format!("{b:.2}")];
            for log in logs {
                let acc = log.acc_under_budget(resource, b);
                cells.push(if acc.is_nan() { "-".into() } else { format!("{acc:.4}") });
            }
            table.row(&cells);
        }
        println!(
            "\n-- best accuracy within {} budget --",
            if resource == 0 { "energy" } else { "money" }
        );
        table.print();
    }

    /// Budget grids spanning the observed cost range across all logs.
    pub fn budget_grid(logs: &[RunLog], resource: usize, points: usize) -> Vec<f64> {
        let max = logs
            .iter()
            .filter_map(|l| l.records.last())
            .map(|r| if resource == 0 { r.energy_j } else { r.money })
            .fold(0.0, f64::max);
        (1..=points).map(|i| max * i as f64 / points as f64).collect()
    }

    /// Print the headline table: resources to reach a target accuracy.
    pub fn print_cost_to_target(logs: &[RunLog], target: f64) {
        let mut table = Table::new(&[
            "mechanism",
            "rounds to target",
            "energy (J)",
            "money",
            "sim time (s)",
        ]);
        for log in logs {
            match log.cost_to_accuracy(target) {
                Some((round, e, m, t)) => table.row(&[
                    log.name.clone(),
                    round.to_string(),
                    format!("{e:.1}"),
                    format!("{m:.4}"),
                    format!("{t:.1}"),
                ]),
                None => table.row(&[
                    log.name.clone(),
                    "never".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        println!("\n-- resources to reach {:.0}% accuracy --", target * 100.0);
        table.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("spin", 2, 50, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
        assert!(acc != 1); // keep the work alive
    }

    #[test]
    fn bench_auto_calibrates() {
        let r = bench_auto("noop-ish", 5.0, || {
            std::hint::black_box(42u64.wrapping_mul(7));
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn json_sink_renders_valid_records() {
        let mut sink = JsonSink {
            bench: "probe".into(),
            records: Vec::new(),
            enabled: false,
        };
        sink.push("a/b/events_per_s", 1234.5, "events/s");
        sink.push("a/b/handoffs", 7.0, "count");
        let text = sink.render();
        assert!(text.starts_with("[\n") && text.ends_with("]\n"));
        assert!(text.contains(
            "{\"bench\": \"probe\", \"case\": \"a/b/events_per_s\", \
             \"value\": 1234.500000, \"unit\": \"events/s\"},"
        ));
        assert!(text.contains("\"case\": \"a/b/handoffs\", \"value\": 7.000000"));
        // Last record carries no trailing comma.
        assert!(text.contains("\"unit\": \"count\"}\n]"));
        sink.finish(); // disabled: must not write anything
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9, // 1 s
            p50_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((r.gib_per_s(1 << 30) - 1.0).abs() < 1e-9);
    }
}
