//! Hierarchical edge aggregation: a per-zone edge tier between devices and
//! the cloud.
//!
//! Until this module every upload terminated at a single flat server — the
//! scenario subsystem gave the world zones, mobility and handoff, but no
//! topology underneath them. Here each scenario zone hosts an [`EdgeNode`]
//! that terminates device uplinks locally and streams *partial aggregates*
//! to the cloud over its own **backhaul** link:
//!
//! - a device's delivered upload is **held** at its zone's edge node (a
//!   [`HeldContribution`]: payload, weight, the metadata the sync mode
//!   needs later);
//! - when a node holds `flush_k` contributions — or the fleet would
//!   otherwise go idle — the node **flushes**: the held set is folded into
//!   one partial-aggregate frame (`4·dim + 32` bytes on the wire,
//!   *independent* of how many contributions were folded — the hierarchical
//!   bandwidth win) and rides the zone's backhaul link as a first-class
//!   in-flight transfer ([`crate::sim::Event::BackhaulArrived`]), so a
//!   round can be backhaul-bound rather than access-bound;
//! - **handoff upgrades from drop-to-restitution to migration**: when a
//!   device changes zone, its contributions still held at the old zone's
//!   edge transfer to the new zone's node over the (free, wired)
//!   edge-to-edge path and are counted `migrated_handoff`; only layers
//!   caught mid-flight on a vanished *access* channel still fall back to
//!   the existing `restitute_layer` path, and frames already on the
//!   backhaul wire never migrate;
//! - with the downlink enabled, broadcasts may be **edge-cached**
//!   ([`Edge::down_fetch`]): the cloud ships each model version once per
//!   zone over the backhaul, devices then fetch from their edge — the
//!   cloud-to-edge leg is charged once per `(zone, version)` instead of
//!   once per device.
//!
//! Aggregation numerics: [`Edge::fold_partial`] is the two-level fold —
//! `sum_i w_i·u_i` plus the weight sum, exactly the streaming
//! [`crate::coordinator::Aggregator`] accumulate step — and the unit /
//! property tests pin edge-partial-then-cloud-finalize ≡ flat aggregation
//! within streaming f32 tolerance. The engines deliver the folded frame's
//! *addends* to the existing per-upload server logic at backhaul-arrival
//! time (linear aggregation makes the two orders equivalent; the
//! fully-async staleness weighting is per-contribution by construction),
//! so the backhaul frame models the wire while the server math stays the
//! audited one. With `edge` disabled (the default) none of this code runs
//! and every engine stays bit-for-bit on the frozen `step_round` oracle.
//! See DESIGN.md §"Hierarchical edge aggregation".

use std::collections::BTreeMap;

use crate::channels::{ChannelType, FadingParams, Link};
use crate::compression::LgcUpdate;
use crate::scenario::{diurnal_trace, ChannelDynamics, TraceReplay};
use crate::util::Rng;

/// Which dynamics source drives the backhaul fading chains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackhaulDynamics {
    /// The parameterized Markov chain (default).
    Markov,
    /// Deterministic day/night sinusoid (metro backhaul load curve).
    Diurnal,
}

impl BackhaulDynamics {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "markov" => Ok(BackhaulDynamics::Markov),
            "diurnal" => Ok(BackhaulDynamics::Diurnal),
            other => Err(format!("unknown edge dynamics `{other}` (markov|diurnal)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackhaulDynamics::Markov => "markov",
            BackhaulDynamics::Diurnal => "diurnal",
        }
    }
}

/// Validated `[edge]` configuration (the config module parses the TOML
/// tree into this).
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeSettings {
    /// Backhaul technology per zone (one link per zone).
    pub backhaul: ChannelType,
    /// Static backhaul bandwidth scale in `(0, 1]` (throttled backhaul).
    pub bw_scale: f64,
    /// Contributions a node folds before streaming one partial-aggregate
    /// frame to the cloud (≥ 1).
    pub flush_k: usize,
    /// Cache downlink broadcasts at the edge (one cloud→edge transfer per
    /// zone per model version).
    pub cache_downlink: bool,
    pub dynamics: BackhaulDynamics,
}

impl Default for EdgeSettings {
    fn default() -> Self {
        EdgeSettings {
            backhaul: ChannelType::G5,
            bw_scale: 1.0,
            flush_k: 4,
            cache_downlink: false,
            dynamics: BackhaulDynamics::Markov,
        }
    }
}

impl EdgeSettings {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.bw_scale > 0.0 && self.bw_scale <= 1.0) {
            return Err(format!("edge bw_scale {} not in (0, 1]", self.bw_scale));
        }
        if self.flush_k == 0 {
            return Err("edge flush_k must be >= 1".into());
        }
        Ok(())
    }
}

/// One device contribution parked at an edge node, carrying everything the
/// sync mode needs when the backhaul frame lands at the cloud.
#[derive(Clone, Debug)]
pub struct HeldContribution {
    pub device: usize,
    pub update: LgcUpdate,
    /// Aggregation weight (sample count under `WeightedBySamples`).
    pub weight: f64,
    /// Server model version the device trained on (staleness at apply).
    pub version: u64,
    pub loss: f64,
    pub reward: f64,
    /// Device-side finish wall of the contribution (compute + access
    /// upload), for the finish-percentile columns.
    pub finish_s: f64,
}

/// A flush en route to the cloud: identified by its flush id so reordered
/// backhaul arrivals (fading makes transfer times non-monotonic) pick the
/// right payload back up.
struct InFlight {
    zone: usize,
    held: Vec<HeldContribution>,
}

/// Per-record-window edge counters, drained into each
/// [`crate::metrics::RoundRecord`] (same pattern as the downlink and
/// scenario windows).
#[derive(Clone, Debug, Default)]
pub struct EdgeWindow {
    /// Backhaul bytes this window (partial-aggregate frames + edge-cached
    /// downlink fetches).
    pub backhaul_bytes: u64,
    /// Wall-clock of each backhaul transfer this window (p95 at record
    /// time: a round is *backhaul-bound* when this exceeds the access-link
    /// finish p95).
    pub backhaul_walls: Vec<f64>,
    /// Held contributions migrated edge-to-edge on handoff.
    pub migrated: u64,
}

impl EdgeWindow {
    pub fn take(&mut self) -> EdgeWindow {
        std::mem::take(self)
    }
}

/// One zone's edge aggregation point: the held-contribution buffer and the
/// zone's backhaul link to the cloud.
pub struct EdgeNode {
    backhaul: Link,
    held: Vec<HeldContribution>,
    /// Last `(version, ready_time)` fetched into the zone's downlink cache.
    down_cached: Option<(u64, f64)>,
}

/// The edge tier: one [`EdgeNode`] per scenario zone (a zone-less world
/// gets a single node), the in-flight flush registry, per-device zone
/// tracking for migration, and window/total accounting.
pub struct Edge {
    settings: EdgeSettings,
    nodes: Vec<EdgeNode>,
    in_flight: BTreeMap<u64, InFlight>,
    next_flush: u64,
    /// Zone each device's held/in-flight work is currently homed at —
    /// compared against the scenario's `zone_of` to detect handoffs.
    device_zone: Vec<usize>,
    dim: usize,
    /// Phase-scripted backhaul scale (`[[scenario.phase]] backhaul_scale`),
    /// multiplied onto the static `bw_scale`.
    phase_scale: f64,
    fading: FadingParams,
    trace: Option<std::sync::Arc<[crate::scenario::TracePoint]>>,
    ticks: u64,
    pub window: EdgeWindow,
    migrated_total: u64,
    backhaul_bytes_total: u64,
}

impl Edge {
    /// Build the tier for `n_zones` zones and `n_devices` devices over a
    /// `dim`-parameter model. Backhaul RNG streams fork off the experiment
    /// seed with an edge-private tag, so enabling the tier never perturbs
    /// any existing stream.
    pub fn new(settings: EdgeSettings, n_zones: usize, n_devices: usize, dim: usize, rng: &Rng) -> Self {
        assert!(n_zones >= 1, "edge tier needs at least one zone");
        let trace = match settings.dynamics {
            BackhaulDynamics::Markov => None,
            BackhaulDynamics::Diurnal => Some(diurnal_trace(1024, 240, 0.2)),
        };
        let fading = FadingParams::default();
        let mut nodes = Vec::with_capacity(n_zones);
        for zi in 0..n_zones {
            let link = Link::new(
                settings.backhaul,
                rng,
                0xED6E_0000 ^ (zi as u64).wrapping_mul(0x9E37_79B9),
            );
            nodes.push(EdgeNode { backhaul: link, held: Vec::new(), down_cached: None });
        }
        let mut edge = Edge {
            settings,
            nodes,
            in_flight: BTreeMap::new(),
            next_flush: 0,
            device_zone: vec![0; n_devices],
            dim,
            phase_scale: 1.0,
            fading,
            trace,
            ticks: 0,
            window: EdgeWindow::default(),
            migrated_total: 0,
            backhaul_bytes_total: 0,
        };
        edge.apply_profiles();
        edge
    }

    fn apply_profiles(&mut self) {
        let scale = (self.settings.bw_scale * self.phase_scale).min(1.0);
        for (zi, node) in self.nodes.iter_mut().enumerate() {
            let dynamics = match &self.trace {
                None => ChannelDynamics::Markov,
                Some(pts) => ChannelDynamics::Trace(TraceReplay::new(
                    pts.clone(),
                    zi.wrapping_mul(131).wrapping_add(self.ticks as usize),
                )),
            };
            node.backhaul.apply_profile(true, self.fading, dynamics, scale, 1.0);
        }
    }

    pub fn settings(&self) -> &EdgeSettings {
        &self.settings
    }

    pub fn n_zones(&self) -> usize {
        self.nodes.len()
    }

    /// Bytes of one partial-aggregate frame on the backhaul wire: the
    /// dense folded delta plus a fixed header (version, round, zone, fold
    /// count, weight sum) — independent of how many contributions folded.
    pub fn frame_bytes(&self) -> u64 {
        4 * self.dim as u64 + 32
    }

    /// Advance every backhaul fading chain one round/tick.
    pub fn step_round(&mut self) {
        self.ticks += 1;
        for node in &mut self.nodes {
            node.backhaul.step_round();
        }
    }

    /// Apply a phase-scripted backhaul scale (`backhaul_scale` in the
    /// `[[scenario.phase]]` DSL). No-op when unchanged.
    pub fn set_phase_scale(&mut self, scale: f64) {
        if (scale - self.phase_scale).abs() > f64::EPSILON {
            self.phase_scale = scale;
            self.apply_profiles();
        }
    }

    /// Park a delivered contribution at `zone`'s node and home the device
    /// there.
    pub fn hold(&mut self, zone: usize, c: HeldContribution) {
        self.device_zone[c.device] = zone;
        self.nodes[zone].held.push(c);
    }

    pub fn held_count(&self, zone: usize) -> usize {
        self.nodes[zone].held.len()
    }

    /// Contributions parked or on the backhaul wire — the fleet-idle gate:
    /// the engine must not park the fleet while the edge still owes the
    /// cloud work.
    pub fn pending_total(&self) -> usize {
        self.nodes.iter().map(|n| n.held.len()).sum::<usize>()
            + self.in_flight.values().map(|f| f.held.len()).sum::<usize>()
    }

    /// Whether `zone`'s node has reached its fold threshold.
    pub fn ready_to_flush(&self, zone: usize) -> bool {
        self.nodes[zone].held.len() >= self.settings.flush_k
    }

    /// Fold `zone`'s held set into one partial-aggregate frame and put it
    /// on the backhaul wire. Returns `(flush_id, arrival_time, bytes)` for
    /// the engine to schedule [`crate::sim::Event::BackhaulArrived`], or
    /// `None` when nothing is held.
    pub fn begin_flush(&mut self, zone: usize, now: f64) -> Option<(u64, f64, u64)> {
        if self.nodes[zone].held.is_empty() {
            return None;
        }
        let held = std::mem::take(&mut self.nodes[zone].held);
        let bytes = self.frame_bytes();
        let cost = self.nodes[zone].backhaul.transfer(bytes);
        self.window.backhaul_bytes += bytes;
        self.backhaul_bytes_total += bytes;
        self.window.backhaul_walls.push(cost.time_s);
        let id = self.next_flush;
        self.next_flush += 1;
        self.in_flight.insert(id, InFlight { zone, held });
        Some((id, now + cost.time_s, bytes))
    }

    /// Flush every non-empty node (round teardown / fleet-idle flush).
    /// Returns the scheduled `(zone, flush_id, arrival_time, bytes)` rows.
    pub fn flush_all(&mut self, now: f64) -> Vec<(usize, u64, f64, u64)> {
        (0..self.nodes.len())
            .filter_map(|z| self.begin_flush(z, now).map(|(id, at, by)| (z, id, at, by)))
            .collect()
    }

    /// Claim the payload of an arrived flush (engine's `BackhaulArrived`
    /// handler).
    pub fn take_arrived(&mut self, flush: u64) -> Vec<HeldContribution> {
        self.in_flight
            .remove(&flush)
            .map(|f| f.held)
            .expect("BackhaulArrived without a matching in-flight flush")
    }

    /// Zone the engine last homed `device` at.
    pub fn zone_of(&self, device: usize) -> usize {
        self.device_zone[device]
    }

    /// Handoff: move `device`'s held contributions from their current edge
    /// to `to_zone`'s node (edge-to-edge migration; frames already on the
    /// backhaul wire stay put). Returns the number migrated.
    pub fn migrate(&mut self, device: usize, to_zone: usize) -> u64 {
        let from = self.device_zone[device];
        self.device_zone[device] = to_zone;
        if from == to_zone {
            return 0;
        }
        let (src, dst) = if from < to_zone {
            let (a, b) = self.nodes.split_at_mut(to_zone);
            (&mut a[from], &mut b[0])
        } else {
            let (a, b) = self.nodes.split_at_mut(from);
            (&mut b[0], &mut a[to_zone])
        };
        let mut moved = 0u64;
        let mut i = 0;
        while i < src.held.len() {
            if src.held[i].device == device {
                dst.held.push(src.held.remove(i));
                moved += 1;
            } else {
                i += 1;
            }
        }
        self.window.migrated += moved;
        self.migrated_total += moved;
        moved
    }

    /// Count an accounting-only migration (cohort engines: the slot's
    /// contribution is re-homed without a materialized held buffer).
    pub fn note_migrated(&mut self, n: u64) {
        self.window.migrated += n;
        self.migrated_total += n;
    }

    /// Accounting-only flush for the cohort engines: charge one
    /// partial-aggregate frame on `zone`'s backhaul and return its wall
    /// time (no event, no payload).
    pub fn charge_flush(&mut self, zone: usize) -> f64 {
        let bytes = self.frame_bytes();
        let cost = self.nodes[zone].backhaul.transfer(bytes);
        self.window.backhaul_bytes += bytes;
        self.backhaul_bytes_total += bytes;
        self.window.backhaul_walls.push(cost.time_s);
        cost.time_s
    }

    /// Edge-cached downlink fetch: the time at which model `version` is
    /// available at `zone`'s edge for device broadcast. The first request
    /// per `(zone, version)` charges one dense frame on the backhaul;
    /// subsequent requests hit the cache.
    pub fn down_fetch(&mut self, zone: usize, version: u64, now: f64) -> f64 {
        if let Some((v, ready)) = self.nodes[zone].down_cached {
            if v == version {
                return ready.max(now);
            }
        }
        let bytes = self.frame_bytes();
        let cost = self.nodes[zone].backhaul.transfer(bytes);
        self.window.backhaul_bytes += bytes;
        self.backhaul_bytes_total += bytes;
        self.window.backhaul_walls.push(cost.time_s);
        let ready = now + cost.time_s;
        self.nodes[zone].down_cached = Some((version, ready));
        ready
    }

    /// Whether edge-side downlink caching is on.
    pub fn cache_downlink(&self) -> bool {
        self.settings.cache_downlink
    }

    /// Two-level fold of a held set: `(sum_i w_i·u_i, sum_i w_i, n)` —
    /// the streaming-aggregator accumulate step run at the edge, on the
    /// sparse scatter kernel via [`crate::compression::LgcUpdate::add_into`]
    /// (bitwise-identical per coordinate). The composition test pins
    /// edge-partial-then-cloud-finalize ≡ flat.
    pub fn fold_partial(held: &[HeldContribution], dim: usize) -> (Vec<f32>, f64, usize) {
        let mut acc = vec![0f32; dim];
        let mut wsum = 0f64;
        for c in held {
            c.update.add_into(&mut acc, c.weight as f32);
            wsum += c.weight;
        }
        (acc, wsum, held.len())
    }

    /// Run-total migrated contributions (SimStats).
    pub fn migrated_total(&self) -> u64 {
        self.migrated_total
    }

    /// Run-total backhaul bytes.
    pub fn backhaul_bytes_total(&self) -> u64 {
        self.backhaul_bytes_total
    }

    /// Fresh FL episode: buffers, caches, windows and totals clear; the
    /// backhaul fading streams keep their position (like every other link).
    pub fn reset_episode(&mut self) {
        for node in &mut self.nodes {
            node.held.clear();
            node.down_cached = None;
        }
        self.in_flight.clear();
        self.device_zone.iter_mut().for_each(|z| *z = 0);
        self.phase_scale = 1.0;
        self.window = EdgeWindow::default();
        self.migrated_total = 0;
        self.backhaul_bytes_total = 0;
        self.apply_profiles();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Layer;

    fn upd(dim: usize, vals: &[(u32, f32)]) -> LgcUpdate {
        LgcUpdate {
            dim,
            layers: vec![Layer {
                indices: vals.iter().map(|&(i, _)| i).collect(),
                values: vals.iter().map(|&(_, v)| v).collect(),
            }],
        }
    }

    fn held(device: usize, dim: usize, vals: &[(u32, f32)], weight: f64) -> HeldContribution {
        HeldContribution {
            device,
            update: upd(dim, vals),
            weight,
            version: 0,
            loss: 0.0,
            reward: f64::NAN,
            finish_s: 1.0,
        }
    }

    fn mk(zones: usize, devices: usize) -> Edge {
        Edge::new(EdgeSettings::default(), zones, devices, 8, &Rng::new(7))
    }

    #[test]
    fn flush_charges_one_frame_regardless_of_fold_count() {
        let mut e = mk(1, 4);
        for d in 0..4 {
            e.hold(0, held(d, 8, &[(d as u32, 1.0)], 1.0));
        }
        assert!(e.ready_to_flush(0));
        let (id, arrive, bytes) = e.begin_flush(0, 10.0).unwrap();
        assert_eq!(bytes, 4 * 8 + 32, "frame size independent of fold count");
        assert!(arrive > 10.0);
        assert_eq!(e.pending_total(), 4, "in-flight work still pending");
        let got = e.take_arrived(id);
        assert_eq!(got.len(), 4);
        assert_eq!(e.pending_total(), 0);
        assert_eq!(e.window.backhaul_bytes, bytes);
        assert_eq!(e.window.backhaul_walls.len(), 1);
        assert!(e.begin_flush(0, 11.0).is_none(), "nothing held after flush");
    }

    #[test]
    fn migration_moves_only_the_handed_off_device() {
        let mut e = mk(2, 3);
        e.hold(0, held(0, 8, &[(0, 1.0)], 1.0));
        e.hold(0, held(1, 8, &[(1, 1.0)], 1.0));
        e.hold(0, held(0, 8, &[(2, 1.0)], 1.0));
        assert_eq!(e.migrate(0, 1), 2, "both of device 0's holds move");
        assert_eq!(e.held_count(0), 1);
        assert_eq!(e.held_count(1), 2);
        assert_eq!(e.zone_of(0), 1);
        assert_eq!(e.migrated_total(), 2);
        assert_eq!(e.window.migrated, 2);
        // Same-zone "move" is a no-op.
        assert_eq!(e.migrate(1, 0), 0);
        // In-flight frames never migrate.
        let (id, _, _) = e.begin_flush(1, 0.0).unwrap();
        assert_eq!(e.migrate(0, 0), 0);
        assert_eq!(e.take_arrived(id).len(), 2);
    }

    #[test]
    fn two_level_fold_matches_flat_weighted_aggregation() {
        let dim = 16;
        let mk_held = |device: usize, seed: u32, weight: f64| {
            let vals: Vec<(u32, f32)> = (0..dim as u32)
                .map(|i| (i, ((i * 7 + seed * 13) % 23) as f32 / 11.0 - 1.0))
                .collect();
            held(device, dim, &vals, weight)
        };
        let all: Vec<HeldContribution> =
            (0..6).map(|d| mk_held(d, d as u32 + 1, (d + 1) as f64 * 10.0)).collect();
        // Flat: one streaming fold over everything.
        let (flat_acc, flat_w, _) = Edge::fold_partial(&all, dim);
        // Two-level: zone partials summed at the cloud.
        let (acc_a, w_a, _) = Edge::fold_partial(&all[..3], dim);
        let (acc_b, w_b, _) = Edge::fold_partial(&all[3..], dim);
        let cloud: Vec<f32> = acc_a.iter().zip(&acc_b).map(|(a, b)| a + b).collect();
        assert!((flat_w - (w_a + w_b)).abs() < 1e-9);
        for (f, c) in flat_acc.iter().zip(&cloud) {
            let rel = (f - c).abs() / f.abs().max(1.0);
            assert!(rel < 1e-6, "two-level fold diverged: {f} vs {c}");
        }
        // Finalize (1/weight_sum) is a shared scalar, so partial-then-
        // finalize equals flat-then-finalize within the same tolerance.
        let scale = 1.0 / flat_w as f32;
        for (f, c) in flat_acc.iter().zip(&cloud) {
            assert!((f * scale - c * scale).abs() < 1e-6);
        }
    }

    #[test]
    fn down_fetch_charges_once_per_zone_version() {
        let mut e = mk(2, 1);
        let bytes = e.frame_bytes();
        let r1 = e.down_fetch(0, 3, 5.0);
        assert!(r1 > 5.0);
        assert_eq!(e.window.backhaul_bytes, bytes);
        // Cache hit: same version, no new charge, ready clamped to now.
        let r2 = e.down_fetch(0, 3, 100.0);
        assert_eq!(r2, 100.0);
        assert_eq!(e.window.backhaul_bytes, bytes);
        // New version refetches; other zone charges separately.
        e.down_fetch(0, 4, 101.0);
        e.down_fetch(1, 4, 101.0);
        assert_eq!(e.window.backhaul_bytes, 3 * bytes);
    }

    #[test]
    fn throttled_backhaul_is_slower_and_phase_scale_applies() {
        let mut fast = Edge::new(EdgeSettings::default(), 1, 1, 1024, &Rng::new(3));
        let slow_cfg = EdgeSettings { bw_scale: 0.05, ..EdgeSettings::default() };
        let mut slow = Edge::new(slow_cfg, 1, 1, 1024, &Rng::new(3));
        let wf = fast.charge_flush(0);
        let ws = slow.charge_flush(0);
        assert!(ws > wf, "throttled backhaul must be slower: {ws} vs {wf}");
        // Phase-scripted throttle slows the same edge further.
        let w0 = fast.charge_flush(0);
        fast.set_phase_scale(0.1);
        let w1 = fast.charge_flush(0);
        assert!(w1 > w0, "backhaul_scale phase must slow the backhaul");
    }

    #[test]
    fn reset_episode_clears_state_and_determinism_holds() {
        let mk_run = || {
            let mut e = mk(2, 2);
            e.hold(0, held(0, 8, &[(0, 1.0)], 1.0));
            let (_, a1, _) = e.begin_flush(0, 0.0).unwrap();
            e.step_round();
            e.hold(1, held(1, 8, &[(1, 1.0)], 2.0));
            let (_, a2, _) = e.begin_flush(1, 1.0).unwrap();
            (a1, a2)
        };
        let (a, b) = (mk_run(), mk_run());
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1.to_bits(), b.1.to_bits());
        let mut e = mk(1, 1);
        e.hold(0, held(0, 8, &[(0, 1.0)], 1.0));
        e.begin_flush(0, 0.0);
        e.down_fetch(0, 1, 0.0);
        e.note_migrated(3);
        e.reset_episode();
        assert_eq!(e.pending_total(), 0);
        assert_eq!(e.migrated_total(), 0);
        assert_eq!(e.backhaul_bytes_total(), 0);
        assert_eq!(e.window.backhaul_bytes, 0);
        assert!(e.window.backhaul_walls.is_empty());
    }

    #[test]
    fn settings_validate_and_parse() {
        assert!(EdgeSettings::default().validate().is_ok());
        let bad = EdgeSettings { bw_scale: 0.0, ..EdgeSettings::default() };
        assert!(bad.validate().is_err());
        let bad = EdgeSettings { flush_k: 0, ..EdgeSettings::default() };
        assert!(bad.validate().is_err());
        assert_eq!(BackhaulDynamics::parse("Diurnal").unwrap(), BackhaulDynamics::Diurnal);
        assert_eq!(BackhaulDynamics::parse("markov").unwrap().name(), "markov");
        assert!(BackhaulDynamics::parse("warp").is_err());
    }
}
