//! # lgc — Layered Gradient Compression for Multi-Channeled Federated Learning
//!
//! A from-scratch reproduction of *"Toward Efficient Federated Learning in
//! Multi-Channeled Mobile Edge Network with Layered Gradient Compression"*
//! (Du, Feng, Xiang, Liu — cs.LG 2021) as a three-layer Rust + JAX + Pallas
//! stack, redesigned around **three pluggable seams** so new mechanisms are
//! one-file plug-ins rather than enum surgery:
//!
//! | seam | trait | built-ins |
//! |------|-------|-----------|
//! | compression | [`compression::Compressor`] | `LgcTopAB`, `LgcRadix`, `RandK`, `Qsgd`, `DenseNoop`, composable `ErrorCompensated<C>` |
//! | aggregation | [`coordinator::Aggregator`] | `MeanAggregator`, `WeightedBySamples` (both batch and streaming accumulate/finalize) |
//! | round control | [`coordinator::RoundPolicy`] | `StaticLayered`, `FastestSingle`, `DdpgPolicy` |
//! | client sampling | [`population::ClientSampler`] | `FullParticipation`, `UniformK`, `WeightedBySamples`, `AvailabilityMarkov` |
//!
//! The simulated downlink ([`downlink`]) makes the server's model
//! broadcast a priced, delayed, layered path instead of a free instant
//! sync: per-device mirrors + delta compression (dense exact or LGC
//! layered), per-layer in-flight transfers over downlink fading channels,
//! staleness tracking ([`downlink::SyncState`]), and download energy/money
//! charged against the same budgets as the uplink. Disabled by default —
//! and then bit-for-bit identical to the frozen reference loop. See
//! DESIGN.md §"Downlink & staleness".
//!
//! The scenario subsystem ([`scenario`]) opens the network-world axis:
//! trace-driven channel dynamics behind the
//! [`scenario::ChannelDynamics`] seam (Markov chain with overridable
//! [`channels::FadingParams`], diurnal / congestion-burst /
//! Gilbert–Elliott / CSV trace replay), client mobility over zones with
//! mid-run handoff (vanished channels drop in-flight layers into the
//! error-feedback restitution path), and a scripted TOML timeline DSL
//! (`[[scenario.phase]]`) with named presets in
//! [`scenario::ScenarioRegistry`] (`commute`, `stadium-flash-crowd`,
//! `rural-3g`, `diurnal`). Unconfigured, every engine stays bit-for-bit
//! on the frozen reference loop. See DESIGN.md §"Scenarios, mobility &
//! handoff".
//!
//! Population mode ([`population`]) makes client count a free parameter:
//! a `Population` of cheap per-client specs materializes full devices only
//! for the round's sampled cohort, so resident memory is O(model + cohort)
//! rather than O(population × model) — set `population` / `cohort` /
//! `sampler` in the config (see DESIGN.md §"Population, sampling &
//! streaming aggregation").
//!
//! A *mechanism* is a named preset of the three, looked up in the
//! string-keyed [`coordinator::MechanismRegistry`] and assembled by
//! [`coordinator::ExperimentBuilder`]:
//!
//! ```no_run
//! use lgc::config::ExperimentConfig;
//! use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
//!
//! let cfg = ExperimentConfig { use_runtime: false, ..Default::default() };
//! let mut trainer = NativeLrTrainer::new(&cfg);
//! let mut exp = ExperimentBuilder::new(cfg)
//!     .trainer(&trainer)        // local-training backend
//!     // .compressor(...)       // optional: override the preset's seams
//!     // .aggregator(...)
//!     // .policy(...)
//!     .build()
//!     .expect("build");
//! let log = exp.run(&mut trainer).unwrap();
//! println!("final accuracy {:.3}", log.final_acc());
//! ```
//!
//! The round loop in [`coordinator::experiment`] is mechanism-free: FedAvg,
//! LGC-static, LGC-DRL, Top-k, Rand-K and QSGD differ *only* in their
//! registered preset. Execution runs on the discrete-event engine in
//! [`sim`] — virtual clock, per-layer in-flight transfers, and the
//! [`sim::SyncMode`] seam (`Barrier` reproduces the synchronous loop
//! bit-for-bit; `SemiAsync`/`FullyAsync` are FedBuff/FedAsync-style servers
//! for straggler-heavy edge fleets), with barrier-round device compute
//! parallelized via `std::thread::scope`. See DESIGN.md §"Extension points"
//! and §"Event engine & sync modes" for how to register your own
//! compressor/aggregator/mechanism/sync mode, and EXPERIMENTS.md for
//! measured results and async/straggler scenario recipes.
//!
//! ## The three layers
//!
//! - **L3 (this crate)**: the FL coordinator — server, devices, the
//!   multi-channel mobile-edge network simulator, the layered compression
//!   wire protocol, resource accounting, and the per-device DDPG controller.
//! - **L2** (`python/compile/model.py`): LR / CNN / char-GRU fwd/bwd as JAX
//!   graphs, lowered once to HLO text (AOT) and executed via PJRT from
//!   [`runtime`] (behind the `pjrt` cargo feature; the default build is
//!   dependency-free and uses the native LR path).
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the banded
//!   `Top_{α,β}` sparsification and fused SGD step.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

// Style lints the simulator codebase intentionally trades away: indexed
// loops mirror the paper's per-coordinate math, and small constructors
// without Default keep call sites explicit.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::len_without_is_empty
)]

pub mod bench;
pub mod channels;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod downlink;
pub mod drl;
pub mod edge;
pub mod grid;
pub mod kernels;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod population;
pub mod resources;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testing;
pub mod theory;
pub mod util;
