//! # lgc — Layered Gradient Compression for Multi-Channeled Federated Learning
//!
//! A from-scratch reproduction of *"Toward Efficient Federated Learning in
//! Multi-Channeled Mobile Edge Network with Layered Gradient Compression"*
//! (Du, Feng, Xiang, Liu — cs.LG 2021) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **L3 (this crate)**: the FL coordinator — server, devices, the
//!   multi-channel mobile-edge network simulator, the layered compression
//!   wire protocol, resource accounting, and the per-device DDPG controller.
//! - **L2** (`python/compile/model.py`): LR / CNN / char-GRU fwd/bwd as JAX
//!   graphs, lowered once to HLO text (AOT) and executed via PJRT from
//!   [`runtime`].
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the banded
//!   `Top_{α,β}` sparsification and fused SGD step.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod bench;
pub mod channels;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod drl;
pub mod metrics;
pub mod models;
pub mod resources;
pub mod runtime;
pub mod testing;
pub mod theory;
pub mod util;
