//! Theorem 1 — convergence bound evaluator (paper Sec. 2.2, Eq. 6–8).
//!
//! Computes the right-hand side of Eq. 6 given the problem constants
//! (L, μ, G, σ_m, b), the compression contractions γ_m, and the gap bound H.
//! The validation bench (A3) checks the bound's qualitative behaviour —
//! monotone in H, decreasing in γ and T, and dominating the measured
//! optimality gap on a strongly-convex quadratic federated problem.

/// Problem + algorithm constants for the bound.
#[derive(Clone, Debug)]
pub struct BoundParams {
    /// Smoothness L.
    pub l_smooth: f64,
    /// Strong convexity μ.
    pub mu: f64,
    /// Second-moment bound G² (Assumption 2, Eq. 4b) — G here, squared inside.
    pub g: f64,
    /// Per-device gradient noise σ_m (Assumption 2, Eq. 4a).
    pub sigmas: Vec<f64>,
    /// Mini-batch size b.
    pub batch: usize,
    /// Per-device compression contraction γ_m = K_m / D.
    pub gammas: Vec<f64>,
    /// Gap bound H on the synchronization sets I_m.
    pub h_gap: usize,
    /// Initial distance ‖w⁰ − w*‖².
    pub r0_sq: f64,
}

impl BoundParams {
    pub fn m(&self) -> usize {
        self.sigmas.len()
    }

    pub fn kappa(&self) -> f64 {
        self.l_smooth / self.mu
    }

    pub fn gamma_min(&self) -> f64 {
        self.gammas.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// `a > max{4H/γ, 32κ, H}` (Theorem 1); we take 1.01x the max.
    pub fn a(&self) -> f64 {
        let h = self.h_gap as f64;
        1.01 * (4.0 * h / self.gamma_min())
            .max(32.0 * self.kappa())
            .max(h)
            .max(1.0 + 1e-9)
    }

    /// Constant C of Eq. 7a: `min_m 4aγ_m(1−γ_m²)/(aγ_m − 4H)`.
    pub fn c_const(&self) -> f64 {
        let a = self.a();
        let h = self.h_gap as f64;
        self.gammas
            .iter()
            .map(|&g| {
                let denom = a * g - 4.0 * h;
                if denom <= 0.0 {
                    f64::INFINITY
                } else {
                    4.0 * a * g * (1.0 - g * g) / denom
                }
            })
            .fold(f64::INFINITY, f64::min)
            .max(0.0)
    }

    /// C₁ (Eq. 7b) and C₂ (Eq. 7c).
    pub fn c1_c2(&self) -> (f64, f64) {
        let c = self.c_const();
        let m = self.m() as f64;
        let sum: f64 = self
            .gammas
            .iter()
            .map(|&g| (4.0 - 2.0 * g) * (1.0 + c / (g * g)))
            .sum();
        (192.0 / m * sum, 8.0 / m * sum)
    }

    /// A (Eq. 7d): gradient-noise term.
    pub fn a_term(&self) -> f64 {
        let m = self.m() as f64;
        self.sigmas.iter().map(|s| s * s).sum::<f64>() / (self.batch as f64 * m * m)
    }

    /// B (Eq. 7e) with the η-dependent term evaluated at step size
    /// η⁰ = 8/(μ·a) (its largest value — upper bound over the schedule).
    pub fn b_term(&self) -> f64 {
        let (c1, c2) = self.c1_c2();
        let c = self.c_const();
        let h = self.h_gap as f64;
        let g2 = self.g * self.g;
        let gamma = self.gamma_min();
        let eta0 = 8.0 / (self.mu * self.a());
        (1.5 * self.mu + 3.0 * self.l_smooth)
            * (12.0 * c * g2 * h * h / (gamma * gamma) + c1 * eta0 * eta0 * h.powi(4) * g2)
            + 24.0 * (1.0 + c2 * h * h) * self.l_smooth * g2 * h * h
    }

    /// The full Eq. 6 bound on `E[f(w̄^T)] − f*` after T rounds.
    pub fn bound(&self, t_rounds: usize) -> f64 {
        let t = t_rounds as f64;
        let a = self.a();
        // S = Σ (a+t)² ≥ T³/3 (Eq. 7h); use the exact sum.
        let s: f64 = (0..t_rounds).map(|i| (a + i as f64).powi(2)).sum();
        if s == 0.0 {
            return f64::INFINITY;
        }
        let l = self.l_smooth;
        let mu = self.mu;
        l * a.powi(3) / (4.0 * s) * self.r0_sq
            + 8.0 * l * t * (t + 2.0 * a) / (mu * mu * s) * self.a_term()
            + 128.0 * l * t / (mu.powi(3) * s) * self.b_term()
    }

    /// The O(·) form of Corollary 1 (dominant terms only) — used to check
    /// the asymptotic shape.
    pub fn corollary_rate(&self, t_rounds: usize) -> f64 {
        let t = t_rounds as f64;
        let h = self.h_gap as f64;
        let gamma = self.gamma_min();
        let g2 = self.g * self.g;
        let mu2 = self.mu * self.mu;
        let sig2 = self.sigmas.iter().map(|s| s * s).fold(0.0, f64::max);
        let b = self.batch as f64;
        g2 * h.powi(3) / (mu2 * gamma.powi(3) * t.powi(3))
            + sig2 / (mu2 * b * t)
            + h * sig2 / (mu2 * b * gamma * t * t)
            + g2 * (h * h + h.powi(4)) / (self.mu.powi(3) * gamma * gamma * t * t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        BoundParams {
            l_smooth: 1.0,
            mu: 0.1,
            g: 1.0,
            sigmas: vec![0.5, 0.5, 0.5],
            batch: 64,
            gammas: vec![0.2, 0.2, 0.2],
            h_gap: 2,
            r0_sq: 1.0,
        }
    }

    #[test]
    fn bound_finite_and_positive() {
        let p = params();
        let b = p.bound(1000);
        assert!(b.is_finite() && b > 0.0, "{b}");
    }

    #[test]
    fn bound_decreases_in_t() {
        let p = params();
        let b1 = p.bound(1_000);
        let b2 = p.bound(10_000);
        let b3 = p.bound(100_000);
        assert!(b1 > b2 && b2 > b3, "{b1} {b2} {b3}");
    }

    #[test]
    fn bound_increases_in_h() {
        let mut p = params();
        let b1 = p.bound(10_000);
        p.h_gap = 8;
        let b2 = p.bound(10_000);
        assert!(b2 > b1, "H=2: {b1}, H=8: {b2}");
    }

    #[test]
    fn bound_decreases_with_less_compression() {
        let mut p = params();
        let aggressive = p.bound(10_000);
        p.gammas = vec![0.9, 0.9, 0.9]; // keep 90% of coordinates
        let light = p.bound(10_000);
        assert!(light < aggressive, "γ=0.9: {light}, γ=0.2: {aggressive}");
    }

    #[test]
    fn a_respects_constraints() {
        let p = params();
        let a = p.a();
        assert!(a > 4.0 * p.h_gap as f64 / p.gamma_min());
        assert!(a > 32.0 * p.kappa());
        assert!(a > p.h_gap as f64);
    }

    #[test]
    fn c_const_positive_finite() {
        let p = params();
        let c = p.c_const();
        assert!(c.is_finite() && c > 0.0, "{c}");
    }

    #[test]
    fn corollary_rate_t3_term_dominates_small_t_noise_term_large_t() {
        let p = params();
        // As T grows, the rate decays at least like 1/T (noise term).
        let r1 = p.corollary_rate(100);
        let r2 = p.corollary_rate(10_000);
        assert!(r2 < r1 / 50.0, "{r1} vs {r2}");
    }

    #[test]
    fn noise_term_scales_inverse_batch() {
        let mut p = params();
        let a1 = p.a_term();
        p.batch *= 4;
        let a2 = p.a_term();
        assert!((a1 / a2 - 4.0).abs() < 1e-9);
    }
}
