//! `lgc` — launcher CLI for the LGC federated-learning framework.
//!
//! ```text
//! lgc train [--config=FILE] [--key=value ...]         run one experiment
//! lgc compare [--mechanisms=a,b] [--key=value ...]    run registered mechanisms, same seed
//! lgc compare-grid [--mechanisms=..] [--scenarios=..] mechanism × scenario × sync grid,
//!                  [--sync_modes=..]                  ranked table + CSV + markdown
//! lgc report <trace.jsonl> [--topk=N] [--chrome=F]    drill into a recorded trace
//! lgc info                                            runtime / artifact info
//! ```
//!
//! Overrides use the config keys (see `ExperimentConfig`), e.g.:
//! `lgc train --mechanism=lgc --workload=cnn --rounds=200 --csv=out.csv`

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};
use lgc::config::ExperimentConfig;
use lgc::coordinator::{
    ExperimentBuilder, LocalTrainer, MechanismRegistry, NativeLrTrainer, PjrtTrainer,
};
use lgc::grid::{run_grid, select_mechanisms, GridSpec};
use lgc::metrics::RunLog;
use lgc::runtime::Runtime;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "compare" => cmd_compare(rest),
        "compare-grid" => cmd_compare_grid(rest),
        "report" => cmd_report(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `lgc help`)"),
    }
}

fn print_usage() {
    let mechanisms = MechanismRegistry::builtin().names().join("|");
    let scenarios = lgc::scenario::ScenarioRegistry::builtin().names().join("|");
    println!(
        "lgc — Layered Gradient Compression FL framework\n\n\
         USAGE:\n  lgc train   [--config=FILE] [--key=value ...]\n  \
         lgc compare [--mechanisms=a,b,c] [--key=value ...]\n  \
         lgc compare-grid [--mechanisms=a,b,c] [--scenarios=s1,s2]\n  \
                   [--sync_modes=m1,m2] [--target_acc=F] [--budget_j=F]\n  \
                   [--csv=FILE] [--key=value ...]\n  \
         lgc report <trace.jsonl> [--topk=N] [--chrome=FILE]\n  \
         lgc info [--artifacts_dir=DIR]\n\n\
         report reads a trace recorded with --trace=true (or\n\
         --trace_file=FILE) and prints round-time attribution, channel\n\
         utilization, backhaul occupancy, and a straggler top-k;\n\
         --chrome= exports Chrome trace-event JSON for Perfetto.\n\n\
         compare runs every registered mechanism (subset via --mechanisms=)\n\
         with the same seed; compare-grid crosses mechanisms with scenarios\n\
         (default none,diurnal) and sync modes (default barrier,semi-async)\n\
         and prints a ranked table (acc@budget, time-to-target, J/round),\n\
         CSV, and an EXPERIMENTS.md-ready block.\n\n\
         Common keys: mechanism={mechanisms}, workload=lr|cnn|rnn,\n\
         rounds=N, devices=M, lr=F, h_fixed=N, h_max=N, energy_budget=F,\n\
         money_budget=F, seed=N, use_runtime=true|false, csv=FILE,\n\
         sync_mode=barrier|semi-async|fully-async, buffer_k=N,\n\
         staleness_decay=F, compute_threads=N (0 = all cores),\n\
         shards=N (event-queue shards, 0 = auto),\n\
         population=N, cohort=K, sampler=full|uniform-k|\
         weighted-by-samples|availability-markov,\n\
         churn_down=P, churn_up=P, streaming=true|false,\n\
         downlink=true|false, downlink_compression=dense|layered,\n\
         downlink_tariff_scale=F,\n\
         edge=true|false, edge.backhaul=3g|4g|5g,\n\
         edge.bw_scale=F, edge.flush_k=N, edge.cache_downlink=true|false,\n\
         edge.dynamics=markov|diurnal,\n\
         scenario=none|{scenarios},\n\
         scenario_file=FILE (TOML [scenario] tree: zones, mobility,\n\
         [[scenario.phase]] timeline),\n\
         trace=true|false, trace_file=FILE (JSONL event trace),\n\
         profile=true|false (wall-clock phase timers)"
    );
}

/// Split `--config=` and `--csv=` out of the overrides.
fn parse_common(args: &[String]) -> (Option<PathBuf>, Option<PathBuf>, Vec<String>) {
    let mut config = None;
    let mut csv = None;
    let mut overrides = Vec::new();
    for a in args {
        if let Some(v) = a.strip_prefix("--config=") {
            config = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--csv=") {
            csv = Some(PathBuf::from(v));
        } else {
            overrides.push(a.clone());
        }
    }
    (config, csv, overrides)
}

/// Build the right trainer for a config.
pub fn make_trainer(cfg: &ExperimentConfig) -> Result<Box<dyn LocalTrainer>> {
    if cfg.use_runtime {
        let rt = Runtime::new(Path::new(&cfg.artifacts_dir)).with_context(|| {
            format!(
                "PJRT runtime init from `{}` — run `make artifacts` first, \
                 or pass --use_runtime=false for the native LR path",
                cfg.artifacts_dir
            )
        })?;
        Ok(Box::new(PjrtTrainer::new(&rt, cfg)?))
    } else {
        Ok(Box::new(NativeLrTrainer::new(cfg)))
    }
}

/// Process peak resident set (VmHWM) in MB, Linux only.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

/// Assemble the end-of-run summary — every line routes through one
/// [`lgc::obs::Report`] so the `key: value` contract (greppable by
/// scripts/CI) has a single owner.
fn report(log: &RunLog) {
    let mut rep = lgc::obs::Report::new();
    rep.gap();
    rep.raw(format!("== {} ==", log.name));
    rep.push("rounds run      ", log.records.len());
    let handoffs: u64 = log.records.iter().map(|r| r.handoffs).sum();
    if handoffs > 0 {
        let dropped: u64 = log.records.iter().map(|r| r.dropped_handoff).sum();
        rep.push(
            "handoffs        ",
            format!("{handoffs} ({dropped} in-flight layers dropped)"),
        );
    }
    let migrated: u64 = log.records.iter().map(|r| r.migrated_handoff).sum();
    let backhaul: u64 = log.records.iter().map(|r| r.backhaul_bytes).sum();
    if backhaul > 0 || migrated > 0 {
        let bound: u64 = log.records.iter().map(|r| r.edge_rounds_bound).sum();
        rep.push(
            "edge backhaul   ",
            format!(
                "{:.2} MB ({bound} backhaul-bound rounds)",
                backhaul as f64 / (1024.0 * 1024.0)
            ),
        );
        rep.push("migrated_handoff", migrated);
    }
    if let Some(last) = log.last() {
        rep.push("final train loss", format!("{:.4}", last.train_loss));
        rep.push("final eval acc  ", format!("{:.4}", log.final_acc()));
        rep.push("best eval acc   ", format!("{:.4}", log.best_acc()));
        rep.push("total energy (J)", format!("{:.1}", last.energy_j));
        rep.push("total money     ", format!("{:.4}", last.money));
        rep.push("total time (s)  ", format!("{:.1}", last.total_time_s));
        let bytes: u64 = log.records.iter().map(|r| r.bytes_up).sum();
        rep.push(
            "total upload    ",
            format!("{:.2} MB", bytes as f64 / (1024.0 * 1024.0)),
        );
        let down: u64 = log.records.iter().map(|r| r.down_bytes).sum();
        if down > 0 {
            let down_j: f64 = log.records.iter().map(|r| r.down_energy_j).sum();
            rep.push(
                "total download  ",
                format!("{:.2} MB", down as f64 / (1024.0 * 1024.0)),
            );
            rep.push("download energy ", format!("{down_j:.1} J"));
        }
    }
    attribution_summary(&mut rep, log);
    // Stable `key: value` line for scripts/CI to grep (stadium smoke pins
    // an upper bound on it at 250k clients). Degrades explicitly off-Linux
    // rather than vanishing.
    match peak_rss_mb() {
        Some(mb) => rep.push("peak_rss_mb", format!("{mb:.0}")),
        None => rep.push("peak_rss_mb", "unavailable"),
    }
    rep.print();
}

/// Fold the per-round attribution columns into the summary: how many
/// rounds each component dominated, and the most frequent critical-path
/// client. Silent when no engine filled the columns (frozen reference
/// loop).
fn attribution_summary(rep: &mut lgc::obs::Report, log: &RunLog) {
    let mut by_label: Vec<(&str, usize)> = Vec::new();
    let mut by_client: Vec<(i64, usize)> = Vec::new();
    for r in &log.records {
        if r.bound_by.is_empty() {
            continue;
        }
        match by_label.iter_mut().find(|(l, _)| *l == r.bound_by) {
            Some(e) => e.1 += 1,
            None => by_label.push((r.bound_by, 1)),
        }
        if r.crit_client >= 0 {
            match by_client.iter_mut().find(|(c, _)| *c == r.crit_client) {
                Some(e) => e.1 += 1,
                None => by_client.push((r.crit_client, 1)),
            }
        }
    }
    if by_label.is_empty() {
        return;
    }
    by_label.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
    let parts: Vec<String> =
        by_label.iter().map(|(l, n)| format!("{l} {n}")).collect();
    rep.push("rounds bound by ", parts.join(", "));
    by_client.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    if let Some((client, n)) = by_client.first() {
        rep.push(
            "top straggler   ",
            format!("client {client} (critical in {n} rounds)"),
        );
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (config, csv, overrides) = parse_common(args);
    let cfg = ExperimentConfig::load(config.as_deref(), &overrides)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "training: mechanism={} workload={} devices={} rounds={} runtime={}",
        cfg.mechanism.name(),
        cfg.workload.model_name(),
        cfg.devices,
        cfg.rounds,
        cfg.use_runtime
    );
    let mut trainer = make_trainer(&cfg)?;
    let mut exp = ExperimentBuilder::new(cfg).trainer(trainer.as_ref()).build()?;
    if let (Some(pop), Some(sampler)) = (&exp.population, &exp.sampler) {
        println!(
            "population: {} clients, cohort {} per round, sampler {}{}",
            pop.len(),
            pop.cohort(),
            sampler.name(),
            if exp.cfg.streaming { ", streaming aggregation" } else { "" }
        );
    }
    if let Some(dl) = &exp.downlink {
        println!(
            "downlink: {} delta compression ({} fidelity), tariff x{}",
            dl.compression().name(),
            if dl.accounting_only() { "accounting" } else { "full" },
            exp.cfg.downlink_tariff_scale
        );
    }
    if let Some(sc) = &exp.scenario {
        println!(
            "scenario: {} ({} zones, {} phases, move_prob {})",
            sc.name(),
            sc.n_zones(),
            sc.n_phases(),
            sc.move_prob()
        );
    }
    if let Some(edge) = &exp.edge {
        let s = edge.settings();
        println!(
            "edge: {} zones, backhaul {} x{} ({}), flush_k {}{}",
            edge.n_zones(),
            s.backhaul.name(),
            s.bw_scale,
            s.dynamics.name(),
            s.flush_k,
            if s.cache_downlink { ", cached downlink" } else { "" }
        );
    }
    match exp.sync_mode {
        lgc::sim::SyncMode::Barrier => println!(
            "sync mode: barrier (compute_threads={})",
            exp.cfg.compute_threads
        ),
        // Async modes pace devices by arrival and run compute inline with
        // event handling — don't advertise a thread count that isn't used.
        mode => println!("sync mode: {} (device compute inline)", mode.name()),
    }
    if exp.recorder.on() {
        match exp.cfg.trace_file.as_deref() {
            Some(path) => println!("trace: recording to {path}"),
            None => println!("trace: recording in memory (set trace_file=FILE to persist)"),
        }
    }
    let log = exp.run(trainer.as_mut())?;
    report(&log);
    if exp.recorder.on() {
        match exp.cfg.trace_file.as_deref() {
            Some(path) => println!(
                "trace: {} records written to {path} (drill down with `lgc report {path}`)",
                exp.recorder.events()
            ),
            None => {
                println!("trace: {} records buffered (discarded at exit)", exp.recorder.events())
            }
        }
    }
    // Wall-clock phase timers, in the bench JSON row shape so the same
    // tooling (python/bench_diff.py) can consume them.
    if exp.recorder.profiling() {
        for ph in lgc::obs::phase::PHASES {
            println!(
                "{{\"bench\": \"profile\", \"case\": \"{}_ms\", \"value\": {:.3}, \"unit\": \"ms\"}}",
                ph.name(),
                exp.recorder.timers.ms(ph)
            );
        }
    }
    if let Some(path) = csv {
        log.write_csv(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<()> {
    let Some(path) = args.iter().find(|a| !a.starts_with("--")) else {
        bail!("usage: lgc report <trace.jsonl> [--topk=N] [--chrome=FILE]");
    };
    let mut topk = 5usize;
    let mut chrome: Option<PathBuf> = None;
    for a in args {
        if let Some(v) = a.strip_prefix("--topk=") {
            topk = v.parse().with_context(|| format!("bad --topk={v}"))?;
        } else if let Some(v) = a.strip_prefix("--chrome=") {
            chrome = Some(PathBuf::from(v));
        } else if a != path && a.starts_with("--") {
            bail!("unknown report flag `{a}` (try --topk=N or --chrome=FILE)");
        }
    }
    let trace = lgc::obs::report::load(path).map_err(|e| anyhow!(e))?;
    print!("{}", lgc::obs::report::render(&trace, topk.max(1)));
    if let Some(out) = chrome {
        std::fs::write(&out, lgc::obs::report::chrome_export(&trace))
            .with_context(|| format!("write chrome export to {}", out.display()))?;
        println!("wrote {} (load in chrome://tracing or Perfetto)", out.display());
    }
    Ok(())
}

/// Pull a `--name=value` flag out of the override list (the remaining
/// overrides pass straight through to the config layer).
fn take_flag(overrides: &mut Vec<String>, name: &str) -> Option<String> {
    let prefix = format!("--{name}=");
    let pos = overrides.iter().position(|a| a.starts_with(&prefix))?;
    let flag = overrides.remove(pos);
    Some(flag[prefix.len()..].to_string())
}

fn cmd_compare(args: &[String]) -> Result<()> {
    let (config, csv, mut overrides) = parse_common(args);
    // The covered set comes from the registry, never a hard-coded list —
    // a newly registered preset joins `lgc compare` automatically.
    let subset = take_flag(&mut overrides, "mechanisms");
    let registry = MechanismRegistry::builtin();
    let mechanisms =
        select_mechanisms(subset.as_deref(), &registry).map_err(|e| anyhow!(e))?;
    println!("comparing {} mechanisms: {}", mechanisms.len(), mechanisms.join(", "));
    for mech in &mechanisms {
        let mut ov = overrides.clone();
        ov.push(format!("--mechanism={mech}"));
        let cfg = ExperimentConfig::load(config.as_deref(), &ov)
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut trainer = make_trainer(&cfg)?;
        let mut exp = ExperimentBuilder::new(cfg).trainer(trainer.as_ref()).build()?;
        // Runs differ by more than mechanism now — say which world each
        // one ran in (the RunLog name carries the same suffix).
        println!(
            "\n[{}] scenario: {}",
            mech,
            exp.scenario.as_ref().map_or("none", |s| s.name())
        );
        let log = exp.run(trainer.as_mut())?;
        report(&log);
        if let Some(base) = &csv {
            let path = base.with_file_name(format!(
                "{}_{}.csv",
                base.file_stem().and_then(|s| s.to_str()).unwrap_or("run"),
                mech
            ));
            log.write_csv(&path)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn cmd_compare_grid(args: &[String]) -> Result<()> {
    let (config, csv, mut overrides) = parse_common(args);
    let registry = MechanismRegistry::builtin();
    let mut spec = GridSpec::default_for(&registry);
    if let Some(subset) = take_flag(&mut overrides, "mechanisms") {
        spec.mechanisms =
            select_mechanisms(Some(&subset), &registry).map_err(|e| anyhow!(e))?;
    }
    if let Some(list) = take_flag(&mut overrides, "scenarios") {
        let scenarios: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if scenarios.is_empty() {
            bail!("empty --scenarios= list");
        }
        spec.scenarios = scenarios;
    }
    if let Some(list) = take_flag(&mut overrides, "sync_modes") {
        let modes: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if modes.is_empty() {
            bail!("empty --sync_modes= list");
        }
        spec.sync_modes = modes;
    }
    if let Some(v) = take_flag(&mut overrides, "target_acc") {
        spec.target_acc = v.parse().with_context(|| format!("bad --target_acc={v}"))?;
    }
    if let Some(v) = take_flag(&mut overrides, "budget_j") {
        spec.budget_j =
            Some(v.parse().with_context(|| format!("bad --budget_j={v}"))?);
    }
    println!(
        "compare-grid: {} mechanisms x {} scenarios x {} sync modes",
        spec.mechanisms.len(),
        spec.scenarios.len(),
        spec.sync_modes.len()
    );
    let grid = run_grid(&spec, config.as_deref(), &overrides, make_trainer)?;
    // Everything below is simulated/deterministic — CI diffs two runs of
    // this stdout to pin rank stability, so no wall clock or RSS here.
    grid.print_table();
    println!("\n-- EXPERIMENTS.md block --\n{}", grid.to_markdown());
    if let Some(path) = csv {
        std::fs::write(&path, grid.to_csv())?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let (_, _, overrides) = parse_common(args);
    let dir = overrides
        .iter()
        .find_map(|a| a.strip_prefix("--artifacts_dir="))
        .unwrap_or("artifacts");
    let rt = Runtime::new(Path::new(dir))?;
    println!("PJRT platform : {}", rt.platform());
    println!("artifacts dir : {dir}");
    println!("batch={} img={} nclass={} vocab={} seq={}",
        rt.manifest.batch, rt.manifest.img, rt.manifest.nclass,
        rt.manifest.vocab, rt.manifest.seq);
    println!("compress: D={} ks={:?}", rt.manifest.compress_d, rt.manifest.compress_ks);
    for (name, meta) in &rt.manifest.models {
        println!(
            "model {name:>4}: P={:>7}  x={:?} ({})",
            meta.params, meta.x_shape, meta.x_dtype
        );
    }
    Ok(())
}
