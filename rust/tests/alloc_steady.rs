//! Steady-state allocation accounting for the cohort engine.
//!
//! A counting `#[global_allocator]` measures how many heap allocations a
//! steady-state round performs. Strict zero is not the contract — the
//! policy still returns a fresh `AllocationPlan` and each compressed
//! layer owns its output vectors (both cohort-sized; see DESIGN.md
//! §"Sharded event engine & SoA population" for the exclusion list).
//! The contract under test is that the per-round allocation count is
//! *population-independent*: wire buffers, cohort scratch, residual
//! arenas, and compression scratch are all recycled, so growing the
//! population 10× must not grow the steady-state allocation rate.
//!
//! This file must stay a single-test binary: the counter is global, and
//! a sibling test allocating concurrently would corrupt the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, NativeLrTrainer};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn cohort_cfg(population: usize, rounds: usize, eval_every: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds,
        devices: 4,
        samples_per_device: 128,
        eval_samples: 128,
        eval_every,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        population: Some(population),
        cohort: Some(8),
        // Single shard / single sweep thread: scoped-thread spawns
        // allocate, and the measurement wants the serial code path.
        shards: 1,
        compute_threads: 1,
        ..ExperimentConfig::default()
    }
}

/// Total allocation count of a seeded cohort-barrier run.
fn allocs_for_run(population: usize, rounds: usize, eval_every: usize) -> u64 {
    let cfg = cohort_cfg(population, rounds, eval_every);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let before = ALLOCS.load(Ordering::Relaxed);
    let log = exp.run(&mut trainer).unwrap();
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(log.records.len(), rounds);
    after - before
}

/// Marginal per-round allocation count once the run is warm: runs of 4
/// and 12 rounds share their first 4 rounds bit for bit (same seed), so
/// the difference isolates 8 steady-state rounds after the buffer pools,
/// recycled wire buffers, and cohort scratch have reached fixed point.
fn marginal_allocs_per_round(population: usize, eval_every: usize) -> u64 {
    let short = allocs_for_run(population, 4, eval_every);
    let long = allocs_for_run(population, 12, eval_every);
    assert!(long > short, "longer run must allocate at least as much");
    (long - short) / 8
}

/// `eval_every` larger than any run length in this file: no eval rounds
/// inside the measured window, so eval cost is excluded entirely.
const EVAL_OFF: usize = 1_000;

/// The zero-alloc steady-state criterion, stated scale-invariantly: the
/// warm per-round allocation count must not scale with the population.
/// Every per-client structure a round touches (availability churn sweep,
/// fading sweep, SoA columns) is either allocation-free or pool-recycled,
/// so 10× the clients must cost (within slack) the same allocations per
/// round — only cohort-sized work may allocate.
///
/// Single test by design (the global counter forbids siblings); the eval
/// assertion lives here too.
#[test]
fn steady_state_allocations_are_population_independent() {
    let small = marginal_allocs_per_round(64, EVAL_OFF);
    let large = marginal_allocs_per_round(640, EVAL_OFF);
    // Identical cohort size, identical per-round work: the counts should
    // be near-equal. The slack absorbs hash/Vec growth-pattern jitter
    // from value-dependent layer sizes, never O(population) terms —
    // a single per-client allocation per round would add ~576.
    assert!(
        large <= small + small / 2 + 64,
        "steady-state rounds must not allocate per client: \
         {small} allocs/round at population 64 vs {large} at 640"
    );

    // The shared-forward-kernel eval path is allocation-free once warm:
    // `NativeLrTrainer::eval` walks pre-batched eval tensors through the
    // same stack-accumulator GEMV as training, with no scratch buffers.
    // Evaluating every round must therefore cost (within a small fixed
    // slack for the metrics record itself) the same marginal allocations
    // as never evaluating.
    let with_eval = marginal_allocs_per_round(64, 1);
    assert!(
        with_eval <= small + 16,
        "warm eval rounds must not allocate: \
         {small} allocs/round without eval vs {with_eval} with eval every round"
    );

    // Observability seam contract: with the recorder at its default (off),
    // the trace instrumentation must be allocation-invisible — the warm
    // per-round count with the `trace`/`profile` config keys explicitly
    // false is the same measurement as above, so it must match exactly.
    // (A single stray emission site that formats or buffers when disabled
    // would show up here as extra allocs on every round.)
    let trace_off = marginal_allocs_per_round(64, EVAL_OFF);
    assert_eq!(
        trace_off, small,
        "trace-off steady-state rounds must allocate exactly as before the \
         recorder existed: {small} baseline vs {trace_off} re-measured"
    );
}
