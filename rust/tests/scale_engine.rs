//! Sharded-engine equivalence suite: the event queue's shard count is a
//! pure performance knob. For every engine (barrier, semi-async, and the
//! population cohort variants) and for the LgcStatic / Qsgd / LgcDrl
//! presets, runs with `shards ∈ {1, 2, 8, 0 (auto)}` must be bitwise
//! identical — the per-shard heaps merge on the global `(time, seq)`
//! order, so shard routing can never reorder two events.
//!
//! See DESIGN.md §"Sharded event engine & SoA population".

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, NativeLrTrainer};
use lgc::metrics::RunLog;
use lgc::sim::SyncMode;

fn base_cfg(mechanism: Mechanism, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 3,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

fn run_log(cfg: ExperimentConfig) -> RunLog {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    exp.run(&mut trainer).unwrap()
}

fn assert_logs_bitwise_equal(a: &RunLog, b: &RunLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label} round {r}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} loss round {r}");
        assert_eq!(x.bytes_up, y.bytes_up, "{label} bytes round {r}");
        assert_eq!(
            x.round_time_s.to_bits(),
            y.round_time_s.to_bits(),
            "{label} round_time round {r}"
        );
        assert_eq!(
            x.total_time_s.to_bits(),
            y.total_time_s.to_bits(),
            "{label} total_time round {r}"
        );
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{label} energy round {r}");
        assert_eq!(x.money.to_bits(), y.money.to_bits(), "{label} money round {r}");
        if x.eval_acc.is_nan() || y.eval_acc.is_nan() {
            assert_eq!(x.eval_acc.is_nan(), y.eval_acc.is_nan(), "{label} eval round {r}");
        } else {
            assert_eq!(x.eval_acc.to_bits(), y.eval_acc.to_bits(), "{label} acc round {r}");
        }
        assert_eq!(x.stale_updates, y.stale_updates, "{label} stale round {r}");
        assert_eq!(x.sampled, y.sampled, "{label} sampled round {r}");
        assert_eq!(x.completed, y.completed, "{label} completed round {r}");
        assert_eq!(
            x.dropped_offline, y.dropped_offline,
            "{label} dropped_offline round {r}"
        );
        assert_eq!(x.down_bytes, y.down_bytes, "{label} down_bytes round {r}");
    }
}

/// Shard counts to sweep against the `shards = 1` baseline; `0` resolves
/// to one shard per core, so the auto path is covered on any CI box.
const SHARD_SWEEP: [usize; 3] = [2, 8, 0];

const PRESETS: [Mechanism; 3] = [Mechanism::LgcStatic, Mechanism::Qsgd, Mechanism::LgcDrl];

/// Permanent-fleet engines: barrier and semi-async runs replay bitwise
/// for every shard count and preset.
#[test]
fn shard_count_is_invisible_for_barrier_and_semi_async() {
    for mech in PRESETS {
        for (mode, mode_name) in [
            (None, "barrier"),
            (Some(SyncMode::SemiAsync { buffer_k: 2 }), "semi-async"),
        ] {
            let mk = |shards: usize| {
                let mut cfg = base_cfg(mech, 6);
                cfg.shards = shards;
                cfg.sync_mode = mode;
                cfg
            };
            let baseline = run_log(mk(1));
            assert_eq!(baseline.records.len(), 6);
            for shards in SHARD_SWEEP {
                let swept = run_log(mk(shards));
                assert_logs_bitwise_equal(
                    &baseline,
                    &swept,
                    &format!("{} {mode_name} shards={shards}", mech.name()),
                );
            }
        }
    }
}

/// Population cohort engines (the SoA store + sharded queue together):
/// cohort-barrier and cohort-semi-async runs with availability churn
/// replay bitwise for every shard count and preset. Churn draws live in
/// per-client private RNG streams, so neither the shard routing nor the
/// sweep thread count can touch them.
#[test]
fn shard_count_is_invisible_for_cohort_engines() {
    for mech in PRESETS {
        for (mode, mode_name) in [
            (None, "cohort-barrier"),
            (Some(SyncMode::SemiAsync { buffer_k: 2 }), "cohort-semi-async"),
        ] {
            let mk = |shards: usize| {
                let mut cfg = base_cfg(mech, 6);
                cfg.population = Some(12);
                cfg.cohort = Some(4);
                cfg.churn_down = 0.2;
                cfg.churn_up = 0.5;
                cfg.shards = shards;
                cfg.sync_mode = mode;
                cfg
            };
            let baseline = run_log(mk(1));
            assert_eq!(baseline.records.len(), 6);
            for shards in SHARD_SWEEP {
                let swept = run_log(mk(shards));
                assert_logs_bitwise_equal(
                    &baseline,
                    &swept,
                    &format!("{} {mode_name} shards={shards}", mech.name()),
                );
            }
        }
    }
}

/// Compute-thread count is equally invisible across the kernel paths:
/// the blocked kernels fix lane/chunk boundaries and combine order at
/// compile time (never from thread count), and barrier-round parallel
/// device compute only splits per-device work across workers — so runs
/// with `compute_threads ∈ {2, 8, 0 (auto)}` replay the single-threaded
/// baseline bitwise for every preset, in both the permanent-fleet and
/// cohort engines.
#[test]
fn compute_thread_count_is_invisible_across_kernel_paths() {
    for mech in PRESETS {
        for (population, cohort, mode_name) in
            [(None, None, "barrier"), (Some(12), Some(4), "cohort-barrier")]
        {
            let mk = |threads: usize| {
                let mut cfg = base_cfg(mech, 6);
                cfg.population = population;
                cfg.cohort = cohort;
                cfg.compute_threads = threads;
                cfg
            };
            let baseline = run_log(mk(1));
            assert_eq!(baseline.records.len(), 6);
            for threads in [2usize, 8, 0] {
                let swept = run_log(mk(threads));
                assert_logs_bitwise_equal(
                    &baseline,
                    &swept,
                    &format!("{} {mode_name} compute_threads={threads}", mech.name()),
                );
            }
        }
    }
}

/// The cohort memory bound survives the SoA refactor: a churning
/// population run materializes at most `cohort` devices at any instant,
/// and the pooled compressor boxes stay bounded by the cohort too.
#[test]
fn cohort_memory_bound_holds_under_churn() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 10);
    cfg.population = Some(24);
    cfg.cohort = Some(4);
    cfg.churn_down = 0.2;
    cfg.churn_up = 0.5;
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 10);
    let pop = exp.population.as_ref().unwrap();
    assert!(pop.peak_materialized() <= 4, "peak {}", pop.peak_materialized());
    assert!(pop.pooled_boxes() <= 4, "pooled boxes {}", pop.pooled_boxes());
}
