//! Cross-module integration tests (no PJRT artifacts required): the full
//! coordinator loop on the native LR path, mechanism comparisons, failure
//! injection, async gaps, and the Theorem-1 validation on a strongly-convex
//! federated quadratic.

use lgc::channels::ChannelType;
use lgc::compression::{lgc_compress, CompressScratch, ErrorFeedback};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, LocalTrainer, NativeLrTrainer};
use lgc::theory::BoundParams;
use lgc::util::Rng;

fn base_cfg(mechanism: Mechanism, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 512,
        eval_samples: 512,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 6,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

fn run(cfg: ExperimentConfig) -> lgc::metrics::RunLog {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    exp.run(&mut trainer).unwrap()
}

#[test]
fn all_mechanisms_reach_usable_accuracy() {
    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::TopK, Mechanism::LgcDrl] {
        let log = run(base_cfg(mech, 40));
        assert!(
            log.best_acc() > 0.55,
            "{} reached only {:.3}",
            mech.name(),
            log.best_acc()
        );
    }
}

#[test]
fn lgc_is_cheaper_than_fedavg_at_same_accuracy() {
    // The paper's headline: LGC reaches target accuracy with a fraction of
    // the energy/money of FedAvg (Figs. 3/4/6, right panels).
    let fed = run(base_cfg(Mechanism::FedAvg, 60));
    let lgc = run(base_cfg(Mechanism::LgcStatic, 60));
    let target = 0.6;
    let (_, fed_e, fed_m, _) = fed.cost_to_accuracy(target).expect("fedavg never hit target");
    let (_, lgc_e, lgc_m, _) = lgc.cost_to_accuracy(target).expect("lgc never hit target");
    assert!(
        lgc_e < fed_e,
        "energy to {target}: lgc {lgc_e:.1} J vs fedavg {fed_e:.1} J"
    );
    assert!(
        lgc_m < fed_m,
        "money to {target}: lgc {lgc_m:.4} vs fedavg {fed_m:.4}"
    );
}

#[test]
fn multi_channel_beats_single_channel_on_time() {
    // Same total coordinate budget, split across 3 channels (LGC) vs pushed
    // through one channel (TopK): layered transmission parallelizes and the
    // slowest-path wall time should not be worse on average.
    let lgc = run(base_cfg(Mechanism::LgcStatic, 40));
    let topk = run(base_cfg(Mechanism::TopK, 40));
    let lgc_t = lgc.records.last().unwrap().total_time_s;
    let topk_t = topk.records.last().unwrap().total_time_s;
    // TopK rides only the fastest channel; static LGC intentionally puts the
    // bulk enhancement layer on cheap-but-slow 3G (the layered-coding
    // mapping), so wall time is worse by a bounded factor while energy wins.
    // The DRL mechanism is what re-balances this tradeoff dynamically.
    assert!(lgc_t < topk_t * 12.0, "lgc {lgc_t} vs topk {topk_t}");
    let lgc_e = lgc.records.last().unwrap().energy_j;
    let topk_e = topk.records.last().unwrap().energy_j;
    // TopK sends everything on the *fastest* (most energy-hungry per MB, 5G)
    // channel; LGC's layered split lands most bytes on cheaper channels.
    assert!(lgc_e < topk_e, "energy: lgc {lgc_e} vs topk {topk_e}");
}

#[test]
fn async_gaps_trade_accuracy_for_bytes() {
    let sync = run(base_cfg(Mechanism::LgcStatic, 30));
    let cfg = base_cfg(Mechanism::LgcStatic, 30);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer).with_sync_gaps(vec![1, 2, 3]);
    let gapped = exp.run(&mut trainer).unwrap();
    let sync_bytes: u64 = sync.records.iter().map(|r| r.bytes_up).sum();
    let gap_bytes: u64 = gapped.records.iter().map(|r| r.bytes_up).sum();
    assert!(gap_bytes < sync_bytes, "{gap_bytes} !< {sync_bytes}");
    // still learns
    assert!(gapped.best_acc() > 0.5, "gapped acc {:.3}", gapped.best_acc());
}

#[test]
fn device_dropout_failure_injection() {
    // A device whose budget dies mid-run must not stall the server: the
    // remaining devices keep improving the model.
    let mut cfg = base_cfg(Mechanism::LgcStatic, 40);
    cfg.energy_budget = 2000.0; // dies midway (comm ~tens of J/round/device)
    let log = run(cfg);
    assert!(log.records.len() >= 10, "ran {} rounds", log.records.len());
    // accuracy from surviving rounds still above chance
    assert!(log.best_acc() > 0.3, "acc {:.3}", log.best_acc());
}

#[test]
fn error_feedback_is_essential_under_heavy_compression() {
    // Ablation: with EF (the default), heavy sparsification still converges;
    // dropping the memory each round (no-EF) must be visibly worse on the
    // same seed/setup. We emulate no-EF by resetting the device memories.
    let cfg = base_cfg(Mechanism::LgcStatic, 30);
    let mut cfg_heavy = cfg.clone();
    cfg_heavy.layer_fracs = vec![0.002, 0.004, 0.008]; // ~1.4% kept
    let with_ef = run(cfg_heavy.clone());

    let mut trainer = NativeLrTrainer::new(&cfg_heavy);
    let mut exp = Experiment::new(cfg_heavy, &trainer);
    let mut no_ef_final = f64::NAN;
    for round in 0..30 {
        for dev in &mut exp.devices {
            dev.reset_compressor(); // kill the memory -> plain (biased) top-k
        }
        if let Some(rec) = exp.step_round(round, &mut trainer).unwrap() {
            if !rec.eval_acc.is_nan() {
                no_ef_final = rec.eval_acc;
            }
        }
    }
    assert!(
        with_ef.final_acc() >= no_ef_final - 0.02,
        "EF {:.3} should not lose to no-EF {no_ef_final:.3}",
        with_ef.final_acc()
    );
}

#[test]
fn theorem1_bound_dominates_measured_gap_on_quadratic() {
    // Strongly-convex federated quadratic: f_m(w) = 0.5||w - c_m||^2,
    // f(w) = mean_m f_m. Optimum w* = mean(c_m). Run Alg. 1 with LGC
    // compression by hand and verify the Eq. 6 bound dominates the measured
    // gap for several (H, gamma) settings (shape check, constants are loose).
    let dim = 64;
    let m = 3;
    let mut rng = Rng::new(5);
    let centers: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();
    let wstar: Vec<f32> = (0..dim)
        .map(|i| centers.iter().map(|c| c[i]).sum::<f32>() / m as f32)
        .collect();
    let f = |w: &[f32]| -> f64 {
        centers
            .iter()
            .map(|c| 0.5 * w.iter().zip(c).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / m as f64
    };
    let fstar = f(&wstar);

    for &(h, k) in &[(1usize, 16usize), (2, 8), (4, 32)] {
        let gamma = k as f64 / dim as f64;
        let t_rounds = 1200;
        // Run compressed local SGD (Alg. 1, exact gradients => sigma = 0).
        let mut global = vec![0f32; dim];
        let mut efs: Vec<ErrorFeedback> = (0..m).map(|_| ErrorFeedback::new(dim)).collect();
        let mut scratch = CompressScratch::default();
        let a = 1.01 * (4.0 * h as f64 / gamma).max(32.0).max(h as f64);
        for t in 0..t_rounds {
            let eta = (8.0 / (1.0 * (a + t as f64))) as f32; // mu = 1
            let mut agg = vec![0f32; dim];
            for dev in 0..m {
                // H local steps from the global model
                let mut w = global.clone();
                for _ in 0..h {
                    for i in 0..dim {
                        let g = w[i] - centers[dev][i];
                        w[i] -= eta * g;
                    }
                }
                let progress: Vec<f32> =
                    global.iter().zip(&w).map(|(&a, &b)| a - b).collect();
                let mut u = Vec::new();
                efs[dev].compensate(&progress, &mut u);
                let upd = lgc_compress(&u, &[k], &mut scratch);
                efs[dev].absorb(&u, &upd);
                upd.add_into(&mut agg, 1.0 / m as f32);
            }
            for i in 0..dim {
                global[i] -= agg[i];
            }
        }
        let gap = f(&global) - fstar;
        let params = BoundParams {
            l_smooth: 1.0,
            mu: 1.0,
            g: centers
                .iter()
                .map(|c| c.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt())
                .fold(0.0, f64::max)
                + 1.0,
            sigmas: vec![0.0; m],
            batch: 1,
            gammas: vec![gamma; m],
            h_gap: h,
            r0_sq: wstar.iter().map(|&x| (x as f64).powi(2)).sum(),
        };
        let bound = params.bound(t_rounds);
        assert!(
            gap <= bound,
            "H={h} k={k}: measured gap {gap:.3e} exceeds bound {bound:.3e}"
        );
        // η^(t) = 8/(μ(a+t)) with a ≥ 4H/γ starts tiny when compression is
        // aggressive, so convergence is slow by design; require clear
        // progress rather than a fixed small gap.
        assert!(gap < 0.1, "H={h} k={k}: did not converge, gap {gap:.3e}");
    }
}

#[test]
fn non_iid_partitions_slow_but_do_not_break_convergence() {
    let mut iid = base_cfg(Mechanism::LgcStatic, 40);
    iid.dirichlet_alpha = f64::INFINITY;
    let mut skew = base_cfg(Mechanism::LgcStatic, 40);
    skew.dirichlet_alpha = 0.1;
    let log_iid = run(iid);
    let log_skew = run(skew);
    assert!(log_iid.best_acc() > 0.55);
    assert!(log_skew.best_acc() > 0.45, "skewed acc {:.3}", log_skew.best_acc());
}

#[test]
fn channel_energy_ordering_shows_in_costs() {
    // Running the same experiment with only-3G vs only-5G channels: 5G is
    // faster but costs more energy per MB (Table 1).
    let mut cfg3 = base_cfg(Mechanism::TopK, 15);
    cfg3.channel_types = vec![ChannelType::G3];
    cfg3.layer_fracs = vec![0.05];
    let mut cfg5 = cfg3.clone();
    cfg5.channel_types = vec![ChannelType::G5];
    let log3 = run(cfg3);
    let log5 = run(cfg5);
    let e3 = log3.records.last().unwrap().energy_j;
    let e5 = log5.records.last().unwrap().energy_j;
    let t3 = log3.records.last().unwrap().total_time_s;
    let t5 = log5.records.last().unwrap().total_time_s;
    assert!(e5 > e3, "5G energy {e5} should exceed 3G {e3}");
    assert!(t5 < t3, "5G time {t5} should beat 3G {t3}");
}

#[test]
fn trainer_init_params_deterministic() {
    let cfg = base_cfg(Mechanism::FedAvg, 1);
    let t1 = NativeLrTrainer::new(&cfg);
    let t2 = NativeLrTrainer::new(&cfg);
    assert_eq!(t1.init_params(), t2.init_params());
}
