//! Golden-trace regression suite: one short seeded experiment per
//! mechanism preset, fingerprinted to a compact per-round hash.
//!
//! Purpose: `tests/sim_engine.rs` proves the engine equals the in-repo
//! `step_round` oracle — but if a future PR changes *both* in the same way
//! (an accidental numeric drift in a shared helper), oracle equality still
//! passes. This suite pins the absolute numbers: each preset's per-round
//! `(train_loss bits, bytes_up, sampled, completed)` stream is folded into
//! an FNV-1a 64 hash and compared against the blessed value committed in
//! `tests/golden/traces.txt`, so silent numeric drift fails loudly.
//!
//! Blessing protocol: if a preset has no entry in the golden file yet, the
//! test computes the fingerprint (asserting two independent runs agree —
//! the determinism half of the contract always runs) and **writes the
//! entry**, pinning it from the first run onward; commit the updated file.
//! After an *intentional* numeric change, re-bless by deleting the stale
//! entries (or running with `LGC_BLESS=1`) and committing the regenerated
//! file.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, ExperimentBuilder, MechanismRegistry, NativeLrTrainer};
use lgc::metrics::RunLog;

/// Both golden tests read-modify-write `tests/golden/traces.txt`; the test
/// harness runs them on parallel threads, so serialize the file access.
static GOLDEN_LOCK: Mutex<()> = Mutex::new(());

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("traces.txt")
}

fn cfg(mechanism: Mechanism) -> ExperimentConfig {
    ExperimentConfig {
        mechanism,
        workload: Workload::LrMnist,
        rounds: 6,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 3,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        seed: 42,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

/// FNV-1a 64 over the trace bytes — tiny, dependency-free, stable.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The compact per-round fingerprint: exact loss and round-time bits
/// (silent numeric drift changes these first; scenario worlds that alter
/// only the timing profile still discriminate), traffic, participation
/// counts, and handoff telemetry.
fn fingerprint(log: &RunLog) -> String {
    let mut buf = String::new();
    for r in &log.records {
        let _ = write!(
            buf,
            "{}:{:016x}:{:016x}:{}:{}:{}:{}:{};",
            r.round,
            r.train_loss.to_bits(),
            r.round_time_s.to_bits(),
            r.bytes_up,
            r.sampled,
            r.completed,
            r.handoffs,
            r.dropped_handoff
        );
    }
    format!("{:016x}", fnv1a(buf.as_bytes()))
}

fn run_once(mechanism: Mechanism) -> String {
    let c = cfg(mechanism);
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = Experiment::new(c, &trainer);
    let log = exp.run(&mut trainer).expect("run");
    assert_eq!(log.records.len(), 6, "{}", mechanism.name());
    fingerprint(&log)
}

/// One short seeded lgc-static run inside a named scenario preset — pins
/// the trace generators, mobility chains, phase application and handoff
/// accounting alongside the per-mechanism numerics.
fn run_once_scenario(preset: &str) -> String {
    let mut c = cfg(Mechanism::LgcStatic);
    // Ten rounds (vs six for the mechanism runs): the virtual clock
    // reliably crosses the stadium preset's first phase boundary (2 s), so
    // every preset's fingerprint captures real scenario action.
    c.rounds = 10;
    c.scenario = Some(
        lgc::scenario::ScenarioRegistry::resolve(preset).expect("builtin preset"),
    );
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = Experiment::new(c, &trainer);
    let log = exp.run(&mut trainer).expect("scenario run");
    assert_eq!(log.records.len(), 10, "{preset}");
    fingerprint(&log)
}

/// The same seeded scenario run with the hierarchical edge tier active
/// (throttled 3G backhaul, semi-async buffering) — pins the backhaul link
/// simulation, partial-aggregate flush cadence and migration accounting.
/// The fingerprint extends the per-round row with the edge telemetry so
/// drift in backhaul timing or migration counts fails even when the model
/// trajectory is unchanged.
fn run_once_edge(preset: &str) -> String {
    let mut c = cfg(Mechanism::LgcStatic);
    c.rounds = 10;
    c.scenario = Some(
        lgc::scenario::ScenarioRegistry::resolve(preset).expect("builtin preset"),
    );
    c.sync_mode = Some(lgc::sim::SyncMode::SemiAsync { buffer_k: 2 });
    c.edge_settings = Some(lgc::edge::EdgeSettings {
        backhaul: lgc::channels::ChannelType::G3,
        bw_scale: 0.2,
        flush_k: 2,
        ..lgc::edge::EdgeSettings::default()
    });
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = Experiment::new(c, &trainer);
    assert!(exp.edge.is_some(), "{preset}: edge tier must build");
    let log = exp.run(&mut trainer).expect("edge scenario run");
    assert_eq!(log.records.len(), 10, "edge {preset}");
    let mut buf = String::new();
    for r in &log.records {
        let _ = write!(
            buf,
            "{}:{:016x}:{:016x}:{}:{}:{}:{:016x};",
            r.round,
            r.train_loss.to_bits(),
            r.round_time_s.to_bits(),
            r.bytes_up,
            r.backhaul_bytes,
            r.migrated_handoff,
            r.backhaul_p95_s.to_bits(),
        );
    }
    format!("{:016x}", fnv1a(buf.as_bytes()))
}

fn load_golden() -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(golden_path()) {
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                map.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
    }
    map
}

fn store_golden(map: &BTreeMap<String, String>) {
    let path = golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir golden");
    let mut text = String::from(
        "# Blessed per-preset trace fingerprints (see tests/golden_trace.rs).\n\
         # Regenerate intentionally with LGC_BLESS=1; commit the result.\n",
    );
    for (k, v) in map {
        let _ = writeln!(text, "{k}={v}");
    }
    std::fs::write(&path, text).expect("write golden file");
}

#[test]
fn golden_traces_per_mechanism_preset() {
    let _guard = GOLDEN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bless_all = std::env::var("LGC_BLESS").map(|v| v == "1").unwrap_or(false);
    let mut golden = load_golden();
    let mut blessed_any = false;
    for mech in [
        Mechanism::LgcStatic,
        Mechanism::FedAvg,
        Mechanism::Qsgd,
        Mechanism::RandK,
        Mechanism::LgcDrl,
    ] {
        let name = mech.name();
        // Determinism is the unconditional half of the contract: two
        // independent builds + runs must fingerprint identically.
        let a = run_once(mech);
        let b = run_once(mech);
        assert_eq!(a, b, "{name}: seeded run is not deterministic");
        match golden.get(name) {
            Some(expected) if !bless_all => {
                assert_eq!(
                    &a, expected,
                    "{name}: trace fingerprint drifted from the blessed value in \
                     tests/golden/traces.txt — if this numeric change is intentional, \
                     re-bless with LGC_BLESS=1 and commit; otherwise a shared helper \
                     has silently changed the numbers"
                );
            }
            _ => {
                golden.insert(name.to_string(), a);
                blessed_any = true;
            }
        }
    }
    // Scenario presets: the same blessing protocol, keyed `scenario-<name>`
    // (lgc-static inside each preset world).
    for preset in ["diurnal", "rural-3g", "commute", "stadium-flash-crowd"] {
        let key = format!("scenario-{preset}");
        let a = run_once_scenario(preset);
        let b = run_once_scenario(preset);
        assert_eq!(a, b, "{key}: seeded scenario run is not deterministic");
        match golden.get(&key) {
            Some(expected) if !bless_all => {
                assert_eq!(
                    &a, expected,
                    "{key}: scenario trace fingerprint drifted from the blessed value — \
                     re-bless with LGC_BLESS=1 if intentional"
                );
            }
            _ => {
                golden.insert(key, a);
                blessed_any = true;
            }
        }
    }
    // Edge-tier scenario runs: same protocol, keyed `scenario-edge-<name>`.
    for preset in ["commute", "stadium-flash-crowd"] {
        let key = format!("scenario-edge-{preset}");
        let a = run_once_edge(preset);
        let b = run_once_edge(preset);
        assert_eq!(a, b, "{key}: seeded edge run is not deterministic");
        match golden.get(&key) {
            Some(expected) if !bless_all => {
                assert_eq!(
                    &a, expected,
                    "{key}: edge trace fingerprint drifted from the blessed value — \
                     re-bless with LGC_BLESS=1 if intentional"
                );
            }
            _ => {
                golden.insert(key, a);
                blessed_any = true;
            }
        }
    }
    if blessed_any {
        store_golden(&golden);
        eprintln!(
            "golden_trace: blessed new fingerprints into {} — commit the file",
            golden_path().display()
        );
    }
    // Distinct mechanisms must not collide: if two presets fingerprint
    // identically the fingerprint lost its discriminating power. The
    // `registry-` entries are excluded — several registry presets are the
    // same numerics under a forced sync mode by design (lgc-semi-async
    // under barrier IS lgc-static; energy-adaptive with an infinite
    // budget IS its static allocation), so collisions there are expected.
    let values: Vec<&String> =
        golden.iter().filter(|(k, _)| !k.starts_with("registry-")).map(|(_, v)| v).collect();
    let unique: std::collections::BTreeSet<&&String> = values.iter().collect();
    assert_eq!(values.len(), unique.len(), "fingerprint collision across presets");
}

/// Registry-completeness suite: every registered mechanism preset must
/// build through [`ExperimentBuilder`] and run under both barrier and
/// semi-async sync, and each (preset, mode) cell gets its own blessed
/// fingerprint keyed `registry-<preset>-<mode>` — auto-blessed on the
/// first CI run (commit the regenerated file), compared forever after.
/// A preset that registers without joining this file shows up as a
/// blessed-entry diff in review, so the suite can't silently go stale.
#[test]
fn registry_completeness_every_preset_runs_and_fingerprints() {
    let _guard = GOLDEN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bless_all = std::env::var("LGC_BLESS").map(|v| v == "1").unwrap_or(false);
    let mut golden = load_golden();
    let mut blessed_any = false;
    let registry = MechanismRegistry::builtin();
    let names: Vec<String> = registry.names().iter().map(|s| s.to_string()).collect();
    assert!(names.len() >= 15, "registry shrank: {names:?}");
    let modes = [
        ("barrier", lgc::sim::SyncMode::Barrier),
        ("semi-async", lgc::sim::SyncMode::SemiAsync { buffer_k: 2 }),
    ];
    for name in &names {
        for (mode_name, mode) in &modes {
            let key = format!("registry-{name}-{mode_name}");
            let run = || {
                let mut c = cfg(Mechanism::parse(name).expect("registry key parses"));
                c.rounds = 3;
                c.sync_mode = Some(*mode);
                let mut trainer = NativeLrTrainer::new(&c);
                let mut exp = ExperimentBuilder::new(c)
                    .trainer(&trainer)
                    .build()
                    .unwrap_or_else(|e| panic!("{key}: preset must build: {e:#}"));
                let log = exp.run(&mut trainer).unwrap_or_else(|e| panic!("{key}: {e:#}"));
                assert!(!log.records.is_empty(), "{key}: ran zero rounds");
                fingerprint(&log)
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{key}: seeded run is not deterministic");
            match golden.get(&key) {
                Some(expected) if !bless_all => {
                    assert_eq!(
                        &a, expected,
                        "{key}: trace fingerprint drifted from the blessed value — \
                         re-bless with LGC_BLESS=1 if this numeric change is intentional"
                    );
                }
                _ => {
                    golden.insert(key, a);
                    blessed_any = true;
                }
            }
        }
    }
    if blessed_any {
        store_golden(&golden);
        eprintln!(
            "golden_trace: blessed registry fingerprints into {} — commit the file",
            golden_path().display()
        );
    }
}
