//! Property-based tests over the library invariants (DESIGN.md §7), using
//! the in-repo mini-proptest harness (`lgc::testing`).

use lgc::channels::{allocate_budget, AllocationPlan, ChannelType, DeviceChannels};
use lgc::compression::{lgc_compress, wire, CompressScratch, ErrorFeedback};
use lgc::config::toml::Document;
use lgc::coordinator::{Aggregator, Server, WeightedBySamples};
use lgc::edge::{Edge, HeldContribution};
use lgc::scenario::{
    congestion_burst_trace, diurnal_trace, dynamics, gilbert_elliott_trace, DynamicsKind,
    Scenario, ScenarioSpec, TraceReplay, ZoneSpec,
};
use lgc::testing::{check, default_cases, gen, Shrink};
use lgc::util::{norm2, Rng};

#[test]
fn prop_layers_partition_topk_support() {
    check(
        0xA1,
        default_cases(),
        |rng| {
            let u = gen::f32_vec(rng, 4096, 1.0);
            let n = u.len();
            let k1 = gen::usize_in(rng, 1, (n / 4).max(1));
            let k2 = gen::usize_in(rng, 1, (n / 4).max(1));
            (u, (k1, k2))
        },
        |(u, (k1, k2))| {
            let ks = [(*k1).min(u.len() / 2).max(1), (*k2).min(u.len() / 2).max(1)];
            let total: usize = ks.iter().sum();
            if total > u.len() {
                return Ok(());
            }
            let upd = lgc_compress(u, &ks, &mut CompressScratch::default());
            let mut seen = std::collections::HashSet::new();
            for l in &upd.layers {
                for &i in &l.indices {
                    if !seen.insert(i) {
                        return Err(format!("index {i} in two layers"));
                    }
                }
            }
            if upd.total_nnz() != total {
                return Err(format!("nnz {} != K {total}", upd.total_nnz()));
            }
            for l in &upd.layers {
                for (&i, &v) in l.indices.iter().zip(&l.values) {
                    if u[i as usize] != v {
                        return Err(format!("value mismatch at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_compression_contraction() {
    check(
        0xA2,
        default_cases(),
        |rng| {
            let u = gen::f32_vec(rng, 2048, 2.0);
            let k = gen::usize_in(rng, 1, u.len());
            (u, k)
        },
        |(u, k)| {
            let k = (*k).min(u.len());
            let upd = lgc_compress(u, &[k], &mut CompressScratch::default());
            let dec = upd.decode();
            let res: Vec<f32> = u.iter().zip(&dec).map(|(a, b)| a - b).collect();
            let gamma = k as f64 / u.len() as f64;
            let lhs = norm2(&res);
            let rhs = (1.0 - gamma) * norm2(u) + 1e-6;
            if lhs <= rhs {
                Ok(())
            } else {
                Err(format!("contraction violated: {lhs} > {rhs}"))
            }
        },
    );
}

#[test]
fn prop_error_feedback_telescopes_exactly() {
    check(
        0xA3,
        default_cases(),
        |rng| {
            let u = gen::f32_vec(rng, 1024, 1.0);
            let k = gen::usize_in(rng, 1, u.len());
            (u, k)
        },
        |(progress, k)| {
            let dim = progress.len();
            let k = (*k).min(dim);
            let mut ef = ErrorFeedback::new(dim);
            let mut u = Vec::new();
            ef.compensate(progress, &mut u);
            let g = lgc_compress(&u, &[k], &mut CompressScratch::default());
            let dec = g.decode();
            ef.absorb(&u, &g);
            for i in 0..dim {
                if ef.memory()[i] + dec[i] != u[i] {
                    return Err(format!("telescoping broken at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_roundtrip() {
    check(
        0xA4,
        default_cases(),
        |rng| {
            let u = gen::f32_vec(rng, 3000, 1.0);
            let k = gen::usize_in(rng, 1, u.len());
            (u, k)
        },
        |(u, k)| {
            let k = (*k).min(u.len());
            let upd = lgc_compress(u, &[k], &mut CompressScratch::default());
            let chunk = wire::encode(u.len(), &upd.layers[0]);
            if chunk.bytes.len() != wire::encoded_len(k) {
                return Err("wrong encoded length".into());
            }
            let (dim, layer) = wire::decode(&chunk).map_err(|e| e.to_string())?;
            if dim != u.len() || layer != upd.layers[0] {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allocation_always_feasible() {
    check(
        0xA5,
        default_cases() * 2,
        |rng| {
            let raw: Vec<f32> = (0..gen::usize_in(rng, 1, 6))
                .map(|_| (rng.range(-1.5, 1.5)) as f32)
                .collect();
            let d = gen::usize_in(rng, 1, 100_000);
            (raw, d)
        },
        |(raw, d)| {
            let fr: Vec<f64> = raw.iter().map(|&x| x as f64).collect();
            let min_total = (*d / 10).max(1).min(64);
            let plan = allocate_budget(&fr, *d, min_total);
            if plan.counts.len() != raw.len() {
                return Err("wrong channel count".into());
            }
            if plan.total() > *d {
                return Err(format!("cap violated: {} > {d}", plan.total()));
            }
            if plan.total() < min_total.min(*d) {
                return Err(format!("floor violated: {} < {min_total}", plan.total()));
            }
            Ok(())
        },
    );
}

#[derive(Clone, Debug)]
struct DocCase(String);

impl Shrink for DocCase {}

#[test]
fn prop_toml_roundtrip() {
    check(
        0xA6,
        default_cases(),
        |rng: &mut Rng| {
            let mut doc = Document::new();
            let sections = ["", "s1", "s2"];
            for (si, sec) in sections.iter().enumerate() {
                for ki in 0..gen::usize_in(rng, 1, 4) {
                    let key = format!("k{si}_{ki}");
                    let v = match rng.index(4) {
                        0 => lgc::config::Value::Int(rng.below(1_000_000) as i64 - 500_000),
                        1 => lgc::config::Value::Float(
                            (rng.normal() * 1280.0).round() / 128.0,
                        ),
                        2 => lgc::config::Value::Str(format!("v{}", rng.below(1000))),
                        _ => lgc::config::Value::Array(vec![
                            lgc::config::Value::Int(rng.below(100) as i64),
                            lgc::config::Value::Int(rng.below(100) as i64),
                        ]),
                    };
                    doc.set(sec, &key, v);
                }
            }
            DocCase(doc.to_string())
        },
        |DocCase(text)| {
            let d1 = Document::parse(text).map_err(|e| e.to_string())?;
            let printed = d1.to_string();
            let d2 = Document::parse(&printed).map_err(|e| e.to_string())?;
            if d1 == d2 {
                Ok(())
            } else {
                Err("parse(print(doc)) != doc".into())
            }
        },
    );
}

#[derive(Clone, Debug)]
struct UpdatesCase {
    dim: usize,
    updates: Vec<lgc::compression::LgcUpdate>,
}

impl Shrink for UpdatesCase {
    fn shrink(&self) -> Vec<Self> {
        if self.updates.len() <= 1 {
            return vec![];
        }
        vec![UpdatesCase { dim: self.dim, updates: self.updates[..1].to_vec() }]
    }
}

#[test]
fn prop_server_aggregation_is_mean_of_decodes() {
    check(
        0xA7,
        default_cases() / 2,
        |rng| {
            let dim = gen::usize_in(rng, 8, 512);
            let m = gen::usize_in(rng, 1, 6);
            let mut updates = Vec::new();
            for _ in 0..m {
                let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let k = gen::usize_in(rng, 1, dim);
                updates.push(lgc_compress(&u, &[k], &mut CompressScratch::default()));
            }
            UpdatesCase { dim, updates }
        },
        |case| {
            let mut server = Server::new(vec![0f32; case.dim]);
            let refs: Vec<&lgc::compression::LgcUpdate> = case.updates.iter().collect();
            server.aggregate_and_apply(&refs);
            let m = case.updates.len() as f32;
            let decodes: Vec<Vec<f32>> = case.updates.iter().map(|u| u.decode()).collect();
            for i in 0..case.dim {
                let expect: f32 = -decodes.iter().map(|d| d[i]).sum::<f32>() / m;
                if (server.params[i] - expect).abs() > 1e-5 {
                    return Err(format!("aggregation mismatch at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fedavg_equals_lgc_full_k() {
    // FedAvg's dense update == LGC with K = D and one layer: the decoded
    // update must be identical for the same progress vector.
    check(
        0xA8,
        default_cases() / 2,
        |rng| gen::f32_vec(rng, 2048, 1.0),
        |progress: &Vec<f32>| {
            let dim = progress.len();
            let upd = lgc_compress(progress, &[dim], &mut CompressScratch::default());
            let dec = upd.decode();
            if &dec != progress {
                return Err("full-K LGC is not identity".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Scenario subsystem: the ChannelDynamics contract (DESIGN.md §"Scenarios,
// mobility & handoff")
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct TraceCase {
    seed: u64,
    len: usize,
    period: usize,
    floor: f64,
    enter: f64,
    exit: f64,
    depth: f64,
    loss: f64,
}

impl Shrink for TraceCase {}

/// Every dynamics source keeps bandwidth multipliers in (0, 1] and loss
/// probabilities in [0, 1) — the contract the channel simulator relies on.
#[test]
fn prop_trace_generators_obey_dynamics_contract() {
    check(
        0xB1,
        default_cases(),
        |rng| TraceCase {
            seed: rng.next_u64(),
            len: gen::usize_in(rng, 2, 600),
            period: gen::usize_in(rng, 1, 512),
            floor: rng.range(0.01, 1.0),
            enter: rng.range(0.0, 0.99),
            exit: rng.range(0.0, 1.0),
            depth: rng.range(0.01, 1.0),
            loss: rng.range(0.0, 0.9),
        },
        |c| {
            let d = diurnal_trace(c.len, c.period, c.floor);
            dynamics::validate_points(&d).map_err(|e| format!("diurnal: {e}"))?;
            let mut r1 = Rng::new(c.seed);
            let b = congestion_burst_trace(c.len, &mut r1, c.enter, c.exit, c.depth, c.loss);
            dynamics::validate_points(&b).map_err(|e| format!("bursts: {e}"))?;
            let mut r2 = Rng::new(c.seed ^ 0xDEAD);
            let g = gilbert_elliott_trace(c.len, &mut r2, c.enter, c.exit, c.depth, c.loss);
            dynamics::validate_points(&g).map_err(|e| format!("GE: {e}"))?;
            Ok(())
        },
    );
}

/// Trace replay is deterministic per seed: the same seed produces the same
/// trace, and two replays starting at the same offset walk identically.
#[test]
fn prop_trace_replay_deterministic_per_seed() {
    check(
        0xB2,
        default_cases(),
        |rng| TraceCase {
            seed: rng.next_u64(),
            len: gen::usize_in(rng, 2, 300),
            period: 1,
            floor: 0.5,
            enter: rng.range(0.0, 0.5),
            exit: rng.range(0.1, 1.0),
            depth: rng.range(0.01, 1.0),
            loss: rng.range(0.0, 0.5),
        },
        |c| {
            let mut ra = Rng::new(c.seed);
            let mut rb = Rng::new(c.seed);
            let a = congestion_burst_trace(c.len, &mut ra, c.enter, c.exit, c.depth, c.loss);
            let b = congestion_burst_trace(c.len, &mut rb, c.enter, c.exit, c.depth, c.loss);
            if a[..] != b[..] {
                return Err("same seed produced different traces".into());
            }
            let offset = (c.seed as usize) % c.len;
            let mut pa = TraceReplay::new(a, offset);
            let mut pb = TraceReplay::new(b, offset);
            for step in 0..3 * c.len {
                if pa.bw().to_bits() != pb.bw().to_bits()
                    || pa.loss().to_bits() != pb.loss().to_bits()
                {
                    return Err(format!("replay diverged at step {step}"));
                }
                pa.advance();
                pb.advance();
            }
            Ok(())
        },
    );
}

#[derive(Clone, Debug)]
struct ProjCase {
    counts: Vec<usize>,
    mask: Vec<bool>,
}

impl Shrink for ProjCase {}

/// Plan projection onto the zone's channel mask preserves the coordinate
/// budget exactly and silences every masked channel.
#[test]
fn prop_plan_projection_preserves_budget() {
    check(
        0xB3,
        default_cases() * 2,
        |rng| {
            let n = gen::usize_in(rng, 1, 6);
            let counts: Vec<usize> = (0..n).map(|_| rng.index(5000)).collect();
            let mut mask: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.5).collect();
            let force = rng.index(n);
            mask[force] = true; // the zone invariant: never zero channels
            ProjCase { counts, mask }
        },
        |c| {
            let plan = AllocationPlan { counts: c.counts.clone() };
            match plan.project_onto(&c.mask) {
                None => {
                    if c.mask.iter().all(|&u| u) {
                        Ok(())
                    } else {
                        Err("projection skipped despite a masked channel".into())
                    }
                }
                Some(p) => {
                    if p.counts.len() != c.counts.len() {
                        return Err("projection changed channel count".into());
                    }
                    if p.total() != plan.total() {
                        return Err(format!(
                            "budget not preserved: {} -> {}",
                            plan.total(),
                            p.total()
                        ));
                    }
                    for (i, (&cnt, &up)) in p.counts.iter().zip(&c.mask).enumerate() {
                        if !up && cnt > 0 {
                            return Err(format!("masked channel {i} still carries {cnt}"));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

#[derive(Clone, Debug)]
struct ZonesCase {
    seed: u64,
    /// Per zone: non-empty subset of the 3 default channel types.
    subsets: Vec<Vec<usize>>,
}

impl Shrink for ZonesCase {
    fn shrink(&self) -> Vec<Self> {
        if self.subsets.len() <= 1 {
            return vec![];
        }
        vec![ZonesCase { seed: self.seed, subsets: self.subsets[..1].to_vec() }]
    }
}

/// A handoff never leaves a device with zero channels: any scenario built
/// from non-empty zone channel sets keeps at least one link up under every
/// mobility/phase history.
#[test]
fn prop_handoff_never_strands_a_device() {
    let types = [ChannelType::G5, ChannelType::G4, ChannelType::G3];
    check(
        0xB4,
        default_cases() / 2,
        |rng| {
            let nz = gen::usize_in(rng, 1, 4);
            let subsets = (0..nz)
                .map(|_| {
                    let mut s: Vec<usize> = (0..3).filter(|_| rng.uniform() < 0.5).collect();
                    if s.is_empty() {
                        s.push(rng.index(3));
                    }
                    s
                })
                .collect();
            ZonesCase { seed: rng.next_u64(), subsets }
        },
        |c| {
            let zones: Vec<ZoneSpec> = c
                .subsets
                .iter()
                .enumerate()
                .map(|(i, subset)| ZoneSpec {
                    name: format!("z{i}"),
                    channels: subset.iter().map(|&k| types[k]).collect(),
                    bw_scale: 1.0,
                    fading: Default::default(),
                    dynamics: DynamicsKind::Markov,
                })
                .collect();
            let spec = ScenarioSpec {
                name: "prop".into(),
                move_prob: 0.5,
                start_spread: true,
                trace_len: 16,
                zones,
                phases: Vec::new(),
                noma: false,
            };
            let mut sc = Scenario::new(spec, 4, &types, &Rng::new(c.seed))
                .map_err(|e| format!("build: {e}"))?;
            let rng = Rng::new(c.seed ^ 1);
            let mut ch = DeviceChannels::new(&types, &rng, 0);
            for t in 0..12 {
                sc.tick(t as f64);
                for id in 0..4 {
                    sc.configure(id, &mut ch);
                    if ch.first_up().is_none() {
                        return Err(format!("device {id} stranded with zero channels"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// NOMA shared-uplink contention (arXiv 2003.01344): with `noma = true`
/// co-zone devices split one carrier per technology, so the *aggregate*
/// effective bandwidth across a zone never exceeds the single-carrier
/// capacity — under any seed, zone count, mobility history, and fading
/// state. And with a single device (1 device/zone), NOMA must reduce to
/// independent links: bit-for-bit the same bandwidths as `noma = false`.
#[test]
fn prop_noma_co_zone_aggregate_bounded_by_carrier_capacity() {
    let types = [ChannelType::G5, ChannelType::G4, ChannelType::G3];
    check(
        0xC7,
        default_cases() / 2,
        |rng| {
            let nz = gen::usize_in(rng, 1, 3);
            let n = gen::usize_in(rng, 2, 6);
            (rng.next_u64(), nz, n)
        },
        |(seed, nz, n)| {
            let spec = |noma: bool| ScenarioSpec {
                name: "noma-prop".into(),
                move_prob: 0.4,
                start_spread: true,
                trace_len: 16,
                zones: (0..*nz)
                    .map(|i| ZoneSpec {
                        name: format!("z{i}"),
                        channels: types.to_vec(),
                        bw_scale: 1.0,
                        fading: Default::default(),
                        dynamics: DynamicsKind::Markov,
                    })
                    .collect(),
                phases: Vec::new(),
                noma,
            };
            let mut sc = Scenario::new(spec(true), *n, &types, &Rng::new(*seed))
                .map_err(|e| format!("build: {e}"))?;
            if !sc.noma() {
                return Err("noma flag lost in the built scenario".into());
            }
            let rng = Rng::new(seed ^ 1);
            let mut ch = DeviceChannels::new(&types, &rng, 0);
            for t in 0..8 {
                sc.tick(t as f64);
                let mut agg = vec![[0f64; 3]; *nz];
                for id in 0..*n {
                    sc.configure(id, &mut ch);
                    let z = sc.zone_of(id);
                    for link in &ch.links {
                        let slot =
                            types.iter().position(|&ty| ty == link.ty).expect("known type");
                        agg[z][slot] += link.effective_bandwidth();
                    }
                }
                for z in 0..*nz {
                    for (slot, ty) in types.iter().enumerate() {
                        let cap = ty.bandwidth_mb_s();
                        if agg[z][slot] > cap + 1e-9 {
                            return Err(format!(
                                "tick {t}: zone {z} {:?} aggregate {} exceeds the \
                                 single-carrier capacity {cap}",
                                ty, agg[z][slot]
                            ));
                        }
                    }
                }
            }
            // One device total ⇒ every zone count is ≤ 1, and NOMA must be
            // indistinguishable from independent links.
            let mut sa = Scenario::new(spec(true), 1, &types, &Rng::new(*seed))
                .map_err(|e| format!("build noma: {e}"))?;
            let mut sb = Scenario::new(spec(false), 1, &types, &Rng::new(*seed))
                .map_err(|e| format!("build plain: {e}"))?;
            let mut cha = DeviceChannels::new(&types, &Rng::new(seed ^ 2), 0);
            let mut chb = DeviceChannels::new(&types, &Rng::new(seed ^ 2), 0);
            for t in 0..8 {
                sa.tick(t as f64);
                sb.tick(t as f64);
                sa.configure(0, &mut cha);
                sb.configure(0, &mut chb);
                for (la, lb) in cha.links.iter().zip(&chb.links) {
                    if la.effective_bandwidth().to_bits() != lb.effective_bandwidth().to_bits()
                    {
                        return Err(format!(
                            "tick {t}: single-device NOMA diverged from independent \
                             links ({} vs {})",
                            la.effective_bandwidth(),
                            lb.effective_bandwidth()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_downlink_frame_roundtrip_and_truncation_safety() {
    // The downlink frame format honors the same invariants as the uplink
    // wire format: encode→decode identity on valid frames, and no panic on
    // any truncation of a valid encoding (DESIGN.md §"Downlink &
    // staleness").
    use lgc::downlink::frame;
    check(
        0xA9,
        default_cases() / 2,
        |rng| {
            let dim = gen::usize_in(rng, 8, 1024);
            let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let k = gen::usize_in(rng, 1, dim / 2);
            (u, k)
        },
        |(u, k)| {
            let dim = u.len();
            let upd = lgc_compress(u, &[(*k).max(1).min(dim)], &mut CompressScratch::default());
            let layer = &upd.layers[0];
            let mut buf = Vec::new();
            let n = frame::encode_frame(3, 11, 0, 1, dim, layer, &mut buf);
            if n != frame::frame_len(layer.len()) {
                return Err(format!("frame bytes {n} != {}", frame::frame_len(layer.len())));
            }
            let mut out = lgc::compression::Layer { indices: vec![], values: vec![] };
            let hdr = frame::decode_frame(&buf, &mut out).map_err(|e| e.to_string())?;
            if hdr.dim != dim || &out != layer {
                return Err("frame roundtrip mismatch".into());
            }
            for cut in 0..buf.len() {
                // Must never panic; any result is acceptable.
                let _ = frame::decode_frame(&buf[..cut], &mut out);
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Streaming aggregation & the edge two-level fold (DESIGN.md §"Hierarchical
// edge aggregation")
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct WeightedCase {
    dim: usize,
    updates: Vec<lgc::compression::LgcUpdate>,
    weights: Vec<f64>,
}

impl Shrink for WeightedCase {
    fn shrink(&self) -> Vec<Self> {
        if self.updates.len() <= 1 {
            return vec![];
        }
        vec![WeightedCase {
            dim: self.dim,
            updates: self.updates[..1].to_vec(),
            weights: self.weights[..1].to_vec(),
        }]
    }
}

fn gen_weighted_case(rng: &mut Rng) -> WeightedCase {
    let dim = gen::usize_in(rng, 8, 256);
    let m = gen::usize_in(rng, 1, 6);
    let all_zero = rng.uniform() < 0.2;
    let mut updates = Vec::new();
    let mut weights = Vec::new();
    for _ in 0..m {
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let k = gen::usize_in(rng, 1, dim);
        updates.push(lgc_compress(&u, &[k], &mut CompressScratch::default()));
        weights.push(if all_zero || rng.uniform() < 0.25 {
            0.0
        } else {
            gen::usize_in(rng, 1, 1000) as f64
        });
    }
    WeightedCase { dim, updates, weights }
}

/// `WeightedBySamples` streaming ≡ batch within the documented ~1e-6
/// relative (~1e-5 absolute) tolerance — including degenerate
/// zero-total-weight cohorts, where both paths must apply *nothing*.
#[test]
fn prop_weighted_stream_equals_batch_incl_zero_weight_cohorts() {
    check(
        0xC1,
        default_cases() / 2,
        gen_weighted_case,
        |case| {
            let refs: Vec<&lgc::compression::LgcUpdate> = case.updates.iter().collect();
            let mut batch_agg = WeightedBySamples::new();
            batch_agg.set_round_weights(&case.weights);
            let mut batch = vec![0f32; case.dim];
            batch_agg.aggregate(&refs, &mut batch);

            let mut agg = WeightedBySamples::new();
            if !agg.stream_begin(case.dim) {
                return Err("WeightedBySamples must stream natively".into());
            }
            let mut acc = vec![0f32; case.dim];
            for (u, &w) in case.updates.iter().zip(&case.weights) {
                agg.stream_accumulate(u, w, &mut acc);
            }
            agg.stream_finalize(&mut acc, case.updates.len(), case.weights.iter().sum());

            for i in 0..case.dim {
                let (s, b) = (acc[i], batch[i]);
                if (s - b).abs() > 1e-5 + 1e-6 * b.abs() {
                    return Err(format!("at {i}: stream {s} vs batch {b}"));
                }
            }
            let wsum: f64 = case.weights.iter().sum();
            if wsum == 0.0 && batch.iter().any(|&x| x != 0.0) {
                return Err("zero-total-weight cohort must apply nothing".into());
            }
            Ok(())
        },
    );
}

#[derive(Clone, Debug)]
struct TwoLevelCase {
    dim: usize,
    held: Vec<HeldContribution>,
    zones: Vec<usize>,
    n_zones: usize,
}

impl Shrink for TwoLevelCase {}

/// The edge tier's two-level fold composes: per-zone
/// [`Edge::fold_partial`] partials summed and normalized at the cloud
/// equal the flat weighted aggregation of the same contributions, within
/// streaming f32 tolerance — regardless of how devices shard over zones.
#[test]
fn prop_edge_two_level_fold_composes_to_flat_aggregation() {
    check(
        0xC2,
        default_cases() / 2,
        |rng| {
            let dim = gen::usize_in(rng, 8, 256);
            let m = gen::usize_in(rng, 1, 8);
            let n_zones = gen::usize_in(rng, 1, 4);
            let mut held = Vec::new();
            let mut zones = Vec::new();
            for d in 0..m {
                let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let k = gen::usize_in(rng, 1, dim);
                held.push(HeldContribution {
                    device: d,
                    update: lgc_compress(&u, &[k], &mut CompressScratch::default()),
                    weight: gen::usize_in(rng, 1, 1000) as f64,
                    version: 0,
                    loss: 0.0,
                    reward: f64::NAN,
                    finish_s: 0.0,
                });
                zones.push(rng.index(n_zones));
            }
            TwoLevelCase { dim, held, zones, n_zones }
        },
        |case| {
            // Edge tier: fold each zone's held set, ship the partials, and
            // finalize at the cloud by the total weight.
            let mut acc = vec![0f64; case.dim];
            let mut wsum = 0f64;
            let mut folded = 0usize;
            for z in 0..case.n_zones {
                let zone_held: Vec<HeldContribution> = case
                    .held
                    .iter()
                    .zip(&case.zones)
                    .filter(|(_, &zz)| zz == z)
                    .map(|(c, _)| c.clone())
                    .collect();
                if zone_held.is_empty() {
                    continue;
                }
                let (partial, w, n) = Edge::fold_partial(&zone_held, case.dim);
                if n != zone_held.len() {
                    return Err(format!("zone {z}: folded {n} of {}", zone_held.len()));
                }
                for (a, &p) in acc.iter_mut().zip(&partial) {
                    *a += p as f64;
                }
                wsum += w;
                folded += n;
            }
            if folded != case.held.len() {
                return Err("zones must partition the held set".into());
            }
            // Flat reference: Σ w_i·u_i / Σ w in f64 over the decodes.
            let decodes: Vec<Vec<f32>> = case.held.iter().map(|c| c.update.decode()).collect();
            let wref: f64 = case.held.iter().map(|c| c.weight).sum();
            if (wsum - wref).abs() > 1e-9 * wref.max(1.0) {
                return Err(format!("weight sums differ: {wsum} vs {wref}"));
            }
            for i in 0..case.dim {
                let flat: f64 = case
                    .held
                    .iter()
                    .zip(&decodes)
                    .map(|(c, d)| c.weight * d[i] as f64)
                    .sum::<f64>()
                    / wref;
                let two_level = acc[i] / wsum;
                if (two_level - flat).abs() > 1e-5 + 1e-6 * flat.abs() {
                    return Err(format!("at {i}: two-level {two_level} vs flat {flat}"));
                }
            }
            Ok(())
        },
    );
}
