//! Contract tests for the pluggable seams:
//!
//! - every compressor reachable through the built-in mechanism registry
//!   honors the `Compressor` contract (support subset, budget, determinism);
//! - `MeanAggregator` reproduces the seed's hard-coded
//!   `Server::aggregate_and_apply` numerics **bit-for-bit**;
//! - the server's reusable wire round-trip preserves updates exactly and
//!   its byte accounting matches `Layer::wire_bytes()`.

use lgc::compression::{CompressScratch, Compressor, LayerBudget, LgcUpdate};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Aggregator, BuildCtx, MeanAggregator, MechanismRegistry, Server};
use lgc::util::Rng;

const DIM: usize = 512;

fn test_cfg() -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        devices: 2,
        rounds: 4,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

/// A gradient-like vector with an explicitly empty support region, so the
/// "decode support ⊆ input support" check is non-vacuous.
fn test_input(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..DIM)
        .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() as f32 })
        .collect()
}

/// Build the compressor of a registered mechanism for device `id`.
fn build_compressor(reg: &MechanismRegistry, key: &str, id: usize) -> Box<dyn Compressor> {
    let cfg = test_cfg();
    let static_ks = [8usize, 24, 96];
    let rng = Rng::new(cfg.seed);
    let ctx = BuildCtx { cfg: &cfg, nparams: DIM, static_ks: &static_ks, rng: &rng };
    let preset = reg.get(key).expect("registered preset");
    (preset.compressor)(&ctx, id)
}

#[test]
fn every_registered_compressor_honors_the_contract() {
    let reg = MechanismRegistry::builtin();
    let budget = LayerBudget::new(vec![8, 24, 96]);
    for key in reg.names() {
        let mut scratch = CompressScratch::default();
        let mut c = build_compressor(&reg, key, 0);
        let u = test_input(1);
        let g = c.compress(&u, &budget, &mut scratch);
        let name = c.name();

        // 1. shape: decodes to the input dimension
        assert_eq!(g.dim, DIM, "[{key}/{name}] wrong dim");

        // 2. support subset: nothing materializes at zero input coordinates
        let dec = g.decode();
        for i in 0..DIM {
            if dec[i] != 0.0 {
                assert!(
                    u[i] != 0.0,
                    "[{key}/{name}] shipped mass at empty coordinate {i}"
                );
            }
        }

        // 3. budget: nnz bounded when the compressor claims it
        if c.respects_budget() {
            assert!(
                g.total_nnz() <= budget.total(),
                "[{key}/{name}] nnz {} > budget {}",
                g.total_nnz(),
                budget.total()
            );
        }

        // 4. wire accounting is positive for a nonzero update, and sparse-
        // wire compressors must charge exactly what the sparse format
        // carries (the channel simulator bills `layer_wire_bytes`)
        assert!(g.total_nnz() == 0 || c.wire_bytes(&g) > 0, "[{key}/{name}] zero wire bytes");
        if c.sparse_wire() {
            for layer in &g.layers {
                assert_eq!(
                    c.layer_wire_bytes(layer, g.dim),
                    layer.wire_bytes(),
                    "[{key}/{name}] charged bytes differ from sparse wire bytes"
                );
            }
        }

        // 5. determinism under a fixed seed: a fresh instance from the same
        // factory reproduces the exact same update sequence
        let mut c2 = build_compressor(&reg, key, 0);
        let mut scratch2 = CompressScratch::default();
        let g2 = c2.compress(&u, &budget, &mut scratch2);
        assert_eq!(g, g2, "[{key}/{name}] non-deterministic first round");
        // ... including stateful rounds (error memory, RNG streams)
        let u_next = test_input(2);
        let h1 = c.compress(&u_next, &budget, &mut scratch);
        let h2 = c2.compress(&u_next, &budget, &mut scratch2);
        assert_eq!(h1, h2, "[{key}/{name}] non-deterministic second round");

        // 6. reset clears any error memory
        c.reset();
        if let Some(mem) = c.error_memory() {
            assert_eq!(mem.norm2(), 0.0, "[{key}/{name}] reset left memory");
        }
    }
}

#[test]
fn distinct_devices_get_independent_streams() {
    // Per-device factories must not share RNG state: stochastic compressors
    // on different devices should produce different draws.
    let reg = MechanismRegistry::builtin();
    let budget = LayerBudget::new(vec![32]);
    let u = test_input(3);
    let mut scratch = CompressScratch::default();
    let mut a = build_compressor(&reg, "rand-k", 0);
    let mut b = build_compressor(&reg, "rand-k", 1);
    let ga = a.compress(&u, &budget, &mut scratch);
    let gb = b.compress(&u, &budget, &mut scratch);
    assert_ne!(ga, gb, "device 0 and 1 drew identical rand-k masks");
}

/// The seed's aggregation loop, verbatim: zero the buffer, add each decode
/// scaled by 1/M, subtract from params.
fn seed_aggregate_and_apply(params: &mut [f32], uploads: &[&LgcUpdate]) {
    let mut agg = vec![0f32; params.len()];
    let scale = 1.0 / uploads.len() as f32;
    for upd in uploads {
        upd.add_into(&mut agg, scale);
    }
    for (p, &g) in params.iter_mut().zip(&agg) {
        *p -= g;
    }
}

#[test]
fn mean_aggregator_matches_seed_numerics_bit_for_bit() {
    let mut rng = Rng::new(42);
    for trial in 0..10 {
        let dim = 64 + rng.index(512);
        let m = 1 + rng.index(6);
        let updates: Vec<LgcUpdate> = (0..m)
            .map(|_| {
                let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let k = 1 + rng.index(dim / 2);
                lgc::compression::lgc_compress(&u, &[k], &mut CompressScratch::default())
            })
            .collect();
        let refs: Vec<&LgcUpdate> = updates.iter().collect();

        let init: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut server = Server::new(init.clone());
        server.aggregate_and_apply(&refs);

        let mut expect = init;
        seed_aggregate_and_apply(&mut expect, &refs);

        for i in 0..dim {
            assert_eq!(
                server.params[i].to_bits(),
                expect[i].to_bits(),
                "trial {trial}: bit drift at coordinate {i}"
            );
        }
    }
}

#[test]
fn mean_aggregator_trait_object_matches_direct() {
    // Dispatch through Box<dyn Aggregator> must not change numerics.
    let u: Vec<f32> = (0..128).map(|i| (i as f32).sin()).collect();
    let upd = lgc::compression::lgc_compress(&u, &[32], &mut CompressScratch::default());
    let refs = [&upd, &upd];
    let mut direct = vec![0f32; 128];
    MeanAggregator.aggregate(&refs, &mut direct);
    let mut boxed_out = vec![0f32; 128];
    let mut boxed: Box<dyn Aggregator> = Box::new(MeanAggregator);
    boxed.aggregate(&refs, &mut boxed_out);
    assert_eq!(direct, boxed_out);
}

#[test]
fn server_wire_roundtrip_reuses_buffers_and_accounts_bytes() {
    let mut server = Server::new(vec![0f32; DIM]);
    let mut out = LgcUpdate { dim: 0, layers: Vec::new() };
    let mut scratch = CompressScratch::default();
    for seed in 0..6 {
        let u = test_input(50 + seed);
        let g = lgc::compression::lgc_compress(&u, &[8, 24, 96], &mut scratch);
        server.decode_from_wire_into(&g, &mut out).unwrap();
        assert_eq!(g, out, "roundtrip drift (seed {seed})");
        // The bytes the channels charge per layer are exactly the encoded
        // wire length (header + 8 B/entry) — decode_from_wire_into asserts
        // the same internally; double-check the public accounting here.
        for layer in &g.layers {
            assert_eq!(
                layer.wire_bytes(),
                (lgc::compression::wire::WIRE_HEADER
                    + layer.len() * lgc::compression::WIRE_BYTES_PER_ENTRY)
                    as u64
            );
        }
    }
}
