//! Contract tests for the blocked numeric kernel layer
//! (`rust/src/kernels/`), pinning the determinism policy from DESIGN.md
//! §"Numeric kernels":
//!
//! * per-coordinate kernels are **bitwise-identical** to the scalar loops
//!   they replaced — across lengths straddling the 8-lane remainder and
//!   under NaN/±inf/denormal inputs;
//! * reduction kernels are deterministic with thread-count-independent
//!   chunking ({1, 2, 8, auto} all bit-identical);
//! * the kernel training path and the retained scalar reference path reach
//!   the same final accuracy (within 1e-3) over a seeded LR run — the
//!   guard on the one-time golden-trace re-bless.

use lgc::data::MnistGen;
use lgc::kernels::{self, reference};
use lgc::models::{NativeLr, IMG, LR_PARAMS};
use lgc::util::Rng;

/// Lengths straddling every 8-lane / 4-bank remainder class, plus the
/// parallel-reduction chunk boundary (CHUNK = 4096).
fn sweep_lengths() -> Vec<usize> {
    let mut lens: Vec<usize> = (0..=40).collect();
    lens.extend([255, 256, 257, 783, 784, 785, 4095, 4096, 4097]);
    lens
}

fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Values exercising the IEEE edge cases the kernels must pass through
/// untouched: NaN, ±inf, ±0.0, and f32 denormals.
fn edge_values() -> Vec<f32> {
    vec![
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0,
        -0.0,
        1e-41,  // denormal
        -1e-41, // denormal
        f32::MIN_POSITIVE,
        1.5,
        -2.25e20,
    ]
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} at {i}: {x} vs {y}");
    }
}

#[test]
fn per_coordinate_kernels_bitwise_across_remainders() {
    let mut rng = Rng::new(0xBEEF);
    for len in sweep_lengths() {
        let x = randv(len, &mut rng);
        let base = randv(len, &mut rng);

        let mut y = base.clone();
        let mut yr = base.clone();
        kernels::axpy(0.73, &x, &mut y);
        reference::axpy(0.73, &x, &mut yr);
        assert_bits_eq(&y, &yr, &format!("axpy len {len}"));

        let mut y = base.clone();
        let mut yr = base.clone();
        kernels::scale(-1.37, &mut y);
        reference::scale(-1.37, &mut yr);
        assert_bits_eq(&y, &yr, &format!("scale len {len}"));

        let mut y = base.clone();
        let mut yr = base.clone();
        kernels::scale_add(0.995, &mut y, 0.005, &x);
        reference::scale_add(0.995, &mut yr, 0.005, &x);
        assert_bits_eq(&y, &yr, &format!("scale_add len {len}"));

        let mut y = base.clone();
        let mut yr = base.clone();
        kernels::add_assign(&mut y, &x);
        for (a, &b) in yr.iter_mut().zip(&x) {
            *a += b;
        }
        assert_bits_eq(&y, &yr, &format!("add_assign len {len}"));

        let mut y = base.clone();
        let mut yr = base.clone();
        kernels::sub_assign(&mut y, &x);
        for (a, &b) in yr.iter_mut().zip(&x) {
            *a -= b;
        }
        assert_bits_eq(&y, &yr, &format!("sub_assign len {len}"));
    }
}

#[test]
fn per_coordinate_kernels_bitwise_on_ieee_edge_cases() {
    let edges = edge_values();
    // Every (x, y) pair of edge values, as length-1 and padded slices.
    for &xv in &edges {
        for &yv in &edges {
            for pad in [0usize, 7, 8] {
                let mut x = vec![1.0f32; pad];
                x.push(xv);
                let mut y = vec![2.0f32; pad];
                y.push(yv);

                let mut k = y.clone();
                let mut r = y.clone();
                kernels::axpy(0.5, &x, &mut k);
                reference::axpy(0.5, &x, &mut r);
                assert_bits_eq(&k, &r, &format!("axpy edge ({xv}, {yv}) pad {pad}"));

                let mut k = y.clone();
                let mut r = y.clone();
                kernels::scale_add(0.9, &mut k, 0.1, &x);
                reference::scale_add(0.9, &mut r, 0.1, &x);
                assert_bits_eq(&k, &r, &format!("scale_add edge ({xv}, {yv}) pad {pad}"));

                let mut k = x.clone();
                let mut r = x.clone();
                kernels::scale(f32::INFINITY, &mut k);
                reference::scale(f32::INFINITY, &mut r);
                assert_bits_eq(&k, &r, &format!("scale edge {xv} pad {pad}"));
            }
        }
    }
}

#[test]
fn adam_step_bitwise_vs_inline_expression() {
    let mut rng = Rng::new(0xADA);
    for len in [1usize, 7, 8, 9, 64, 129] {
        let g = randv(len, &mut rng);
        let p0 = randv(len, &mut rng);
        let m0 = randv(len, &mut rng);
        let v0: Vec<f32> = randv(len, &mut rng).iter().map(|v| v * v).collect();
        let (lr, b1, b2, eps, b1t, b2t) = (0.003f32, 0.9f32, 0.999f32, 1e-8f32, 0.1f32, 0.002f32);

        let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
        kernels::adam_step(&mut p, &g, &mut m, &mut v, lr, b1, b2, eps, b1t, b2t);

        let (mut pr, mut mr, mut vr) = (p0, m0, v0);
        for i in 0..len {
            mr[i] = b1 * mr[i] + (1.0 - b1) * g[i];
            vr[i] = b2 * vr[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = mr[i] / b1t;
            let vhat = vr[i] / b2t;
            pr[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
        assert_bits_eq(&p, &pr, &format!("adam p len {len}"));
        assert_bits_eq(&m, &mr, &format!("adam m len {len}"));
        assert_bits_eq(&v, &vr, &format!("adam v len {len}"));
    }
}

#[test]
fn scatter_kernels_bitwise_vs_inline_loops() {
    let mut rng = Rng::new(0x5CA7);
    let dim = 300;
    let idx: Vec<u32> = (0..64).map(|_| rng.index(dim) as u32).collect();
    let vals = randv(idx.len(), &mut rng);
    let base = randv(dim, &mut rng);

    let mut k = base.clone();
    let mut r = base.clone();
    kernels::scatter_add(&mut k, &idx, &vals, 0.25);
    for (&i, &v) in idx.iter().zip(&vals) {
        r[i as usize] += 0.25 * v;
    }
    assert_bits_eq(&k, &r, "scatter_add");

    let mut k = base.clone();
    let mut r = base.clone();
    kernels::scatter_add_unit(&mut k, &idx, &vals);
    for (&i, &v) in idx.iter().zip(&vals) {
        r[i as usize] += v;
    }
    assert_bits_eq(&k, &r, "scatter_add_unit");

    let mut k = base.clone();
    let mut r = base.clone();
    kernels::scatter_sub(&mut k, &idx, &vals);
    for (&i, &v) in idx.iter().zip(&vals) {
        r[i as usize] -= v;
    }
    assert_bits_eq(&k, &r, "scatter_sub");

    let mut k = base.clone();
    let mut r = base.clone();
    kernels::scatter_zero(&mut k, &idx);
    for &i in &idx {
        r[i as usize] = 0.0;
    }
    assert_bits_eq(&k, &r, "scatter_zero");

    let pairs: Vec<(u32, f32)> = idx.iter().zip(&vals).map(|(&i, &v)| (i, v)).collect();
    let mut k = base.clone();
    let mut r = base;
    kernels::scatter_set_pairs(&mut k, &pairs);
    for &(i, v) in &pairs {
        r[i as usize] = v;
    }
    assert_bits_eq(&k, &r, "scatter_set_pairs");
}

#[test]
fn rank1_backward_bitwise_vs_skip_loop_on_sparse_rows() {
    let mut rng = Rng::new(0x0B1);
    for n in [1usize, 3, 4, 5, 97, IMG] {
        // Half-zero rows like the synthetic MNIST pixels, plus a -0.0.
        let mut x: Vec<f32> = (0..n)
            .map(|_| if rng.index(2) == 0 { 0.0 } else { rng.uniform_f32() })
            .collect();
        if n > 2 {
            x[2] = -0.0;
        }
        let mut d = [0f32; 10];
        for dc in d.iter_mut() {
            *dc = rng.normal() as f32;
        }
        let mut gw = vec![0f32; n * 10];
        let mut gw_ref = vec![0f32; n * 10];
        kernels::lr::rank1_acc::<10>(&mut gw, &x, &d);
        reference::rank1_skip::<10>(&mut gw_ref, &x, &d);
        assert_bits_eq(&gw, &gw_ref, &format!("rank1 n {n}"));
    }
}

#[test]
fn chunked_reductions_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0x9A9);
    for len in [0usize, 1, 4095, 4096, 4097, 3 * 4096 + 5, 40_000] {
        let x = randv(len, &mut rng);
        let y = randv(len, &mut rng);
        let d_seq = kernels::reduce::dot_chunked(&x, &y);
        let n_seq = kernels::reduce::norm2_chunked(&x);
        for threads in [1usize, 2, 8, 0] {
            let d = kernels::reduce::par_dot(&x, &y, threads);
            let n = kernels::reduce::par_norm2(&x, threads);
            assert_eq!(d.to_bits(), d_seq.to_bits(), "par_dot len {len} threads {threads}");
            assert_eq!(n.to_bits(), n_seq.to_bits(), "par_norm2 len {len} threads {threads}");
        }
        // Reassociated, but still within numerical shouting distance of the
        // scalar reference.
        let scalar = reference::dot(&x, &y);
        assert!(
            (d_seq - scalar).abs() <= 1e-3 * (1.0 + scalar.abs()),
            "len {len}: chunked {d_seq} vs scalar {scalar}"
        );
    }
}

/// The guard on the one-time golden-trace re-bless: training with the
/// blocked kernels and training with the retained scalar reference path
/// must land at the same final accuracy (within 1e-3) on a seeded LR run.
#[test]
fn kernel_and_scalar_training_agree() {
    let mnist = MnistGen::new(17);
    let train = mnist.dataset(0, 640);
    let eval = mnist.dataset(50_000, 2_000);
    let model = NativeLr::new();
    let batch = 32;
    let nb = train.y.len() / batch;

    let mut p_kernel = vec![0f32; LR_PARAMS];
    let mut p_scalar = vec![0f32; LR_PARAMS];
    let mut grad = vec![0f32; LR_PARAMS];
    for _epoch in 0..15 {
        for b in 0..nb {
            let x = &train.x[b * batch * IMG..(b + 1) * batch * IMG];
            let y = &train.y[b * batch..(b + 1) * batch];
            model.loss_grad(&p_kernel, x, y, &mut grad);
            kernels::axpy(-0.1, &grad, &mut p_kernel);
            model.loss_grad_reference(&p_scalar, x, y, &mut grad);
            for (p, &g) in p_scalar.iter_mut().zip(&grad) {
                *p -= 0.1 * g;
            }
        }
    }

    // The parameter trajectories drift only by reassociation rounding.
    let mut max_rel = 0.0f64;
    for (a, b) in p_kernel.iter().zip(&p_scalar) {
        let rel = ((a - b).abs() / (1e-3 + b.abs())) as f64;
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-2, "kernel vs scalar param drift {max_rel}");

    let n = eval.y.len() as f64;
    let (_, correct_k) = model.eval(&p_kernel, &eval.x, &eval.y);
    let (_, correct_s) = model.eval(&p_scalar, &eval.x, &eval.y);
    let acc_k = correct_k / n;
    let acc_s = correct_s / n;
    assert!(acc_k > 0.5, "kernel path failed to learn: acc {acc_k}");
    assert!(
        (acc_k - acc_s).abs() <= 1e-3,
        "kernel acc {acc_k} vs scalar acc {acc_s}"
    );
}
