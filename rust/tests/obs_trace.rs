//! Telemetry integration tests: trace determinism (two seeded runs emit
//! byte-identical JSONL), the attribution-sums property (per-round
//! components tile the round time exactly), and the trace-off contract
//! (attribution columns stay live, trace buffer stays empty).

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, NativeLrTrainer};
use lgc::obs::{report, Recorder};
use lgc::population::SamplerKind;
use lgc::scenario::ScenarioRegistry;
use lgc::sim::SyncMode;

fn base_cfg(rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 3,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

/// Run with an in-memory trace buffer; return (trace JSONL, run log).
fn traced_run(cfg: ExperimentConfig) -> (String, lgc::metrics::RunLog) {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    exp.recorder = Recorder::to_buffer();
    let log = exp.run(&mut trainer).unwrap();
    (exp.recorder.buffer().to_string(), log)
}

/// Every trace kind an engine may emit — the schema vocabulary, mirrored
/// by `python/trace_check.py`.
const KINDS: &[&str] = &[
    "compute_start",
    "compute_done",
    "uplink_arrive",
    "uplink_drop",
    "backhaul_enqueue",
    "backhaul_arrive",
    "edge_fold",
    "downlink_arrive",
    "sync_confirm",
    "aggregate",
    "handoff",
    "migrate",
    "churn_drop",
    "client_offline",
    "round",
];

fn assert_schema(buf: &str, label: &str) {
    let recs = report::parse(buf).unwrap_or_else(|e| panic!("{label}: {e}"));
    assert!(!recs.is_empty(), "{label}: empty trace");
    for r in &recs {
        assert!(
            KINDS.contains(&r.kind.as_str()),
            "{label}: unknown kind `{}`",
            r.kind
        );
        assert!(r.t.is_finite() && r.t >= 0.0, "{label}: bad t {}", r.t);
    }
}

/// Acceptance criterion: a seeded run with tracing on emits a byte-identical
/// JSONL trace when replayed — across the barrier engine, the legacy
/// semi-async engine, and a population cohort engine.
#[test]
fn seeded_runs_emit_byte_identical_traces() {
    let configs: Vec<(&str, Box<dyn Fn() -> ExperimentConfig>)> = vec![
        ("barrier", Box::new(|| base_cfg(8))),
        (
            "semi-async",
            Box::new(|| {
                let mut cfg = base_cfg(8);
                cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
                cfg
            }),
        ),
        (
            "cohort-semi-async",
            Box::new(|| {
                let mut cfg = base_cfg(8);
                cfg.population = Some(cfg.devices);
                cfg.cohort = Some(cfg.devices);
                cfg.sampler = Some(SamplerKind::Full);
                cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
                cfg
            }),
        ),
    ];
    for (label, make) in &configs {
        let (buf1, log1) = traced_run(make());
        let (buf2, log2) = traced_run(make());
        assert!(!buf1.is_empty(), "{label}: trace must not be empty");
        assert_eq!(buf1, buf2, "{label}: traces must be byte-identical");
        assert_eq!(log1.records.len(), log2.records.len(), "{label}");
        assert_schema(&buf1, label);
        // One round record per RunLog record, in round order.
        let rounds: Vec<_> = report::parse(&buf1)
            .unwrap()
            .into_iter()
            .filter(|r| r.kind == "round")
            .collect();
        assert_eq!(rounds.len(), log1.records.len(), "{label}: round records");
        for (i, r) in rounds.iter().enumerate() {
            assert_eq!(r.round, i as i64, "{label}: round order");
        }
    }
}

/// Acceptance criterion (the attribution-sums property): on the stadium
/// flash-crowd and rural-3g presets, every round record's components
/// (compute + uplink + backhaul + downlink + wait) sum to its round time
/// within 1e-9 — i.e. the report attributes 100% of simulated time.
#[test]
fn attribution_components_sum_to_round_time() {
    let presets: Vec<(&str, Box<dyn Fn() -> ExperimentConfig>)> = vec![
        (
            "stadium-flash-crowd/semi-async",
            Box::new(|| {
                let mut cfg = base_cfg(40);
                cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").unwrap());
                cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
                cfg
            }),
        ),
        (
            "rural-3g/barrier",
            Box::new(|| {
                let mut cfg = base_cfg(14);
                cfg.scenario = Some(ScenarioRegistry::resolve("rural-3g").unwrap());
                cfg
            }),
        ),
    ];
    for (label, make) in &presets {
        let (buf, log) = traced_run(make());
        let rounds: Vec<_> = report::parse(&buf)
            .unwrap()
            .into_iter()
            .filter(|r| r.kind == "round")
            .collect();
        assert_eq!(rounds.len(), log.records.len(), "{label}");
        for r in &rounds {
            let parts = [r.compute, r.uplink, r.backhaul, r.downlink, r.wait];
            assert!(
                parts.iter().all(|v| v.is_finite() && *v >= 0.0),
                "{label} round {}: non-finite/negative component {parts:?}",
                r.round
            );
            let sum: f64 = parts.iter().sum();
            assert!(
                (sum - r.dur).abs() <= 1e-9,
                "{label} round {}: components sum {sum} != round time {}",
                r.round,
                r.dur
            );
        }
        // The trace's verdict matches the RunLog columns.
        for (rec, row) in rounds.iter().zip(&log.records) {
            assert_eq!(rec.bound, row.bound_by, "{label} round {}", rec.round);
            assert_eq!(rec.crit_client, row.crit_client, "{label}");
            assert_eq!(rec.crit_channel, row.crit_channel, "{label}");
        }
    }
}

/// With tracing off (the default), the recorder buffers nothing — but the
/// in-process attribution columns still fill, so `lgc train` summaries and
/// CSVs carry bound_by/crit_client without any trace cost.
#[test]
fn trace_off_keeps_attribution_columns_live() {
    let cfg = base_cfg(8);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert!(!exp.recorder.on(), "trace must default off");
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(exp.recorder.events(), 0);
    assert!(exp.recorder.buffer().is_empty());
    for r in &log.records {
        assert!(!r.bound_by.is_empty(), "round {}: bound_by unset", r.round);
        assert!(r.crit_client >= 0, "round {}: crit_client unset", r.round);
    }
}

/// The report renderer runs end-to-end on a real engine trace and the
/// Chrome export stays structurally sound.
#[test]
fn report_renders_engine_trace_end_to_end() {
    let mut cfg = base_cfg(10);
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    let (buf, _) = traced_run(cfg);
    let trace = report::parse(&buf).unwrap();
    let text = report::render(&trace, 3);
    assert!(text.contains("round-time attribution"), "{text}");
    assert!(text.contains("attributed: 100.00%"), "{text}");
    assert!(text.contains("channel utilization"), "{text}");
    let chrome = report::chrome_export(&trace);
    assert!(chrome.starts_with("{\"traceEvents\":[\n"));
    assert!(chrome.trim_end().ends_with("]}"));
}
