//! Event-engine integration tests: bit-for-bit equivalence of the engine's
//! barrier mode against the synchronous reference loop, thread-count
//! invariance of parallel device compute, async-mode straggler behavior,
//! and the lossy-upload error-feedback regression.

use lgc::channels::{AllocationPlan, ChannelType, DeviceChannels, Fading};
use lgc::compression::{ErrorCompensated, LgcTopAB};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{
    Device, Experiment, ExperimentBuilder, LocalTrainer, NativeLrTrainer, Server,
};
use lgc::edge::EdgeSettings;
use lgc::metrics::RunLog;
use lgc::resources::{ComputeCostModel, ResourceMeter};
use lgc::scenario::{DynamicsKind, ScenarioRegistry, ScenarioSpec, ZoneSpec};
use lgc::sim::SyncMode;
use lgc::util::Rng;

fn base_cfg(mechanism: Mechanism, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 3,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

/// The pre-engine synchronous loop, stepped by hand — the equivalence
/// oracle.
fn reference_log(cfg: ExperimentConfig) -> RunLog {
    let rounds = cfg.rounds;
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let mut log = RunLog::new("reference");
    for round in 0..rounds {
        match exp.step_round(round, &mut trainer).unwrap() {
            Some(rec) => log.push(rec),
            None => break,
        }
    }
    log
}

fn engine_log(cfg: ExperimentConfig) -> RunLog {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert_eq!(exp.sync_mode, SyncMode::Barrier);
    exp.run(&mut trainer).unwrap()
}

fn assert_logs_bitwise_equal(a: &RunLog, b: &RunLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label} round {r}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} loss round {r}");
        assert_eq!(x.bytes_up, y.bytes_up, "{label} bytes round {r}");
        assert_eq!(
            x.round_time_s.to_bits(),
            y.round_time_s.to_bits(),
            "{label} round_time round {r}"
        );
        assert_eq!(
            x.total_time_s.to_bits(),
            y.total_time_s.to_bits(),
            "{label} total_time round {r}"
        );
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{label} energy round {r}");
        assert_eq!(x.money.to_bits(), y.money.to_bits(), "{label} money round {r}");
        if x.eval_acc.is_nan() || y.eval_acc.is_nan() {
            assert_eq!(x.eval_acc.is_nan(), y.eval_acc.is_nan(), "{label} eval round {r}");
        } else {
            assert_eq!(x.eval_acc.to_bits(), y.eval_acc.to_bits(), "{label} acc round {r}");
        }
        assert_eq!(
            x.finish_p50_s.to_bits(),
            y.finish_p50_s.to_bits(),
            "{label} p50 round {r}"
        );
        assert_eq!(
            x.finish_p95_s.to_bits(),
            y.finish_p95_s.to_bits(),
            "{label} p95 round {r}"
        );
        assert_eq!(x.stale_updates, y.stale_updates, "{label} stale round {r}");
        assert_eq!(x.sampled, y.sampled, "{label} sampled round {r}");
        assert_eq!(x.completed, y.completed, "{label} completed round {r}");
        assert_eq!(
            x.dropped_offline, y.dropped_offline,
            "{label} dropped_offline round {r}"
        );
        assert_eq!(x.handoffs, y.handoffs, "{label} handoffs round {r}");
        assert_eq!(
            x.dropped_handoff, y.dropped_handoff,
            "{label} dropped_handoff round {r}"
        );
        assert_eq!(
            x.zone_p50.to_bits(),
            y.zone_p50.to_bits(),
            "{label} zone_p50 round {r}"
        );
    }
}

/// The acceptance criterion: `BarrierSync` on the event engine reproduces
/// the synchronous loop's per-round train loss and bytes_up exactly
/// (seeded), on LgcStatic and on the other registered mechanism shapes.
#[test]
fn barrier_engine_matches_synchronous_loop_bitwise() {
    for (mech, rounds) in [
        (Mechanism::LgcStatic, 14),
        (Mechanism::FedAvg, 8),
        (Mechanism::Qsgd, 8),
        (Mechanism::RandK, 8),
        // Per-agent decide/observe sequences are preserved, so even the
        // DDPG-controlled mechanism replays bit-for-bit.
        (Mechanism::LgcDrl, 6),
    ] {
        let reference = reference_log(base_cfg(mech, rounds));
        let engine = engine_log(base_cfg(mech, rounds));
        assert_eq!(engine.records.len(), rounds, "{}", mech.name());
        assert_logs_bitwise_equal(&reference, &engine, mech.name());
    }
}

#[test]
fn barrier_engine_matches_reference_with_sync_gaps_and_budget() {
    // Async sync sets + a tight budget: early stop must agree too.
    let mut cfg = base_cfg(Mechanism::LgcStatic, 30);
    cfg.energy_budget = 160.0;
    let mk = |cfg: &ExperimentConfig| {
        let trainer = NativeLrTrainer::new(cfg);
        let exp = Experiment::new(cfg.clone(), &trainer).with_sync_gaps(vec![1, 2, 3]);
        (trainer, exp)
    };
    let (mut tr_a, mut exp_a) = mk(&cfg);
    let mut reference = RunLog::new("reference");
    for round in 0..cfg.rounds {
        match exp_a.step_round(round, &mut tr_a).unwrap() {
            Some(rec) => reference.push(rec),
            None => break,
        }
    }
    let (mut tr_b, mut exp_b) = mk(&cfg);
    let engine = exp_b.run(&mut tr_b).unwrap();
    assert!(reference.records.len() < 30, "budget should bite");
    assert_logs_bitwise_equal(&reference, &engine, "gaps+budget");
}

/// Acceptance criterion: multi-threaded device compute yields identical
/// results to single-threaded.
#[test]
fn multithreaded_compute_is_bitwise_identical_to_sequential() {
    let mut base = base_cfg(Mechanism::LgcStatic, 10);
    base.devices = 4;
    for threads in [2usize, 4, 0 /* auto */] {
        let mut cfg = base.clone();
        cfg.compute_threads = threads;
        let seq = engine_log(base.clone());
        let par = engine_log(cfg);
        assert_logs_bitwise_equal(&seq, &par, &format!("threads={threads}"));
    }
}

/// A trainer survives repeated multi-threaded runs: the engine hands the
/// split handles back after each run, so the second run matches a
/// sequential double-run bit for bit.
#[test]
fn repeated_multithreaded_runs_match_sequential_double_run() {
    let run_twice = |threads: usize| {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 5);
        cfg.compute_threads = threads;
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        let first = exp.run(&mut trainer).unwrap();
        let second = exp.run(&mut trainer).unwrap();
        (first, second)
    };
    let (seq1, seq2) = run_twice(1);
    let (par1, par2) = run_twice(3);
    assert_logs_bitwise_equal(&seq1, &par1, "first run");
    assert_logs_bitwise_equal(&seq2, &par2, "second run");
}

/// Build an experiment where device 2 is a straggler: slow compute, pinned
/// to 3G links that start in Bad fading.
fn straggler_exp(cfg: ExperimentConfig, trainer: &NativeLrTrainer, mode: SyncMode) -> Experiment {
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(trainer)
        .sync_mode(mode)
        .build()
        .unwrap();
    let dev = &mut exp.devices[2];
    dev.compute.seconds_per_step *= 25.0;
    for link in dev.channels.links.iter_mut() {
        link.ty = ChannelType::G3;
        link.fading = Fading::Bad;
    }
    exp
}

/// Acceptance criterion: `SemiAsync` finishes a seeded straggler scenario in
/// strictly less simulated wall time than `BarrierSync` at comparable final
/// accuracy.
#[test]
fn semi_async_beats_barrier_wall_time_under_straggler() {
    let cfg = base_cfg(Mechanism::LgcStatic, 40);
    let run = |mode: SyncMode| {
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = straggler_exp(cfg.clone(), &trainer, mode);
        let log = exp.run(&mut trainer).unwrap();
        (log, exp.sim_stats)
    };
    let (barrier, _) = run(SyncMode::Barrier);
    let (semi, semi_stats) = run(SyncMode::SemiAsync { buffer_k: 2 });
    assert_eq!(barrier.records.len(), 40);
    assert_eq!(semi.records.len(), 40);
    let t_barrier = barrier.last().unwrap().total_time_s;
    let t_semi = semi.last().unwrap().total_time_s;
    assert!(
        t_semi < t_barrier,
        "semi-async {t_semi:.2}s should beat barrier {t_barrier:.2}s"
    );
    assert!(
        barrier.final_acc() > 0.5 && semi.final_acc() > 0.5,
        "both modes should train: barrier {:.3}, semi {:.3}",
        barrier.final_acc(),
        semi.final_acc()
    );
    // The straggler's buffered updates arrive stale, and straggler stats
    // are populated for the async records.
    let stale_total: u64 = semi.records.iter().map(|r| r.stale_updates).sum();
    assert!(stale_total > 0, "straggler contributions should be stale");
    assert_eq!(semi_stats.records, 40);
    assert!(semi_stats.events > 0);
    assert!(semi
        .records
        .iter()
        .all(|r| r.finish_p50_s.is_nan() || r.finish_p95_s >= r.finish_p50_s));
}

#[test]
fn fully_async_trains_and_advances_monotonically() {
    let cfg = base_cfg(Mechanism::LgcStatic, 60);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg.clone())
        .trainer(&trainer)
        .sync_mode(SyncMode::FullyAsync { staleness_decay: 0.8 })
        .build()
        .unwrap();
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 60);
    for w in log.records.windows(2) {
        assert!(w[1].total_time_s >= w[0].total_time_s);
        assert!(w[1].energy_j >= w[0].energy_j);
    }
    assert!(log.final_acc() > 0.35, "acc={}", log.final_acc());
    // Staleness-weighted applications happen (concurrent devices).
    let stale_total: u64 = log.records.iter().map(|r| r.stale_updates).sum();
    assert!(stale_total > 0);
}

#[test]
fn semi_async_preset_resolves_and_runs_end_to_end() {
    let cfg = base_cfg(Mechanism::parse("lgc-semi-async").unwrap(), 12);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert_eq!(exp.sync_mode, SyncMode::SemiAsync { buffer_k: 2 });
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 12);
    assert!(exp.sim_stats.events > 0);
}

#[test]
fn engine_determinism_given_seed_across_modes() {
    for mode in [
        SyncMode::Barrier,
        SyncMode::SemiAsync { buffer_k: 2 },
        SyncMode::FullyAsync { staleness_decay: 0.6 },
    ] {
        let run = || {
            let cfg = base_cfg(Mechanism::LgcStatic, 10);
            let mut trainer = NativeLrTrainer::new(&cfg);
            let mut exp = ExperimentBuilder::new(cfg)
                .trainer(&trainer)
                .sync_mode(mode)
                .build()
                .unwrap();
            exp.run(&mut trainer).unwrap()
        };
        let (a, b) = (run(), run());
        assert_logs_bitwise_equal(&a, &b, mode.name());
    }
}

/// Regression for the lossy-upload seam: a device stuck in Bad fading keeps
/// losing layers, yet training still converges because every lost layer is
/// restituted into the error-feedback memory and the device resyncs after
/// each compressed upload (nothing is silently discarded).
#[test]
fn bad_fading_device_with_lossy_uploads_still_converges() {
    let cfg = ExperimentConfig {
        workload: Workload::LrMnist,
        devices: 1,
        samples_per_device: 512,
        eval_samples: 256,
        use_runtime: false,
        ..ExperimentConfig::default()
    };
    let mut trainer = NativeLrTrainer::new(&cfg);
    let init = trainer.init_params();
    let rng = Rng::new(11);
    let mut dev = Device::new(
        0,
        init.clone(),
        Box::new(ErrorCompensated::new(LgcTopAB)),
        DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &rng,
            0,
        ),
        ResourceMeter::new(f64::INFINITY, f64::INFINITY),
        ComputeCostModel::for_params(init.len()),
    );
    let mut server = Server::new(init);
    let plan = AllocationPlan { counts: vec![80, 120, 200] };
    let mut first_loss = f64::NAN;
    let mut last_loss = f64::NAN;
    let mut lost_total = 0usize;
    for round in 0..80 {
        let loss = dev.local_steps(&mut trainer, 2, 0.05).unwrap();
        if round == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        // Pin every link to Bad fading so erasures keep happening.
        for link in dev.channels.links.iter_mut() {
            link.fading = Fading::Bad;
        }
        let (delivered, _wall, _costs, lost) = dev.compress_and_upload_lossy(&plan);
        lost_total += lost;
        if !delivered.layers.is_empty() {
            let decoded = Server::decode_from_wire(&delivered).unwrap();
            server.aggregate_and_apply(&[&decoded]);
        }
        // Always resync: after compression the round's progress lives in
        // `delivered + error memory`; skipping the sync would double-count
        // the restituted mass.
        dev.sync(&server.params);
    }
    assert!(lost_total > 0, "Bad fading over 80 rounds should lose layers");
    assert!(
        last_loss < 0.7 * first_loss,
        "loss should drop despite erasures: {first_loss:.3} -> {last_loss:.3}"
    );
    let (_, acc) = trainer.eval(&server.params).unwrap();
    assert!(acc > 0.35, "acc={acc}");
}

// ---------------------------------------------------------------------------
// Downlink: disabled = frozen oracle; enabled = charged, staleness-tracked
// ---------------------------------------------------------------------------

/// The tentpole's hard constraint: with the downlink explicitly disabled
/// (the default, and `downlink = false` spelled out), every engine remains
/// bit-for-bit equal to the frozen `step_round` oracle.
#[test]
fn downlink_disabled_stays_bitwise_equal_to_oracle() {
    for mech in [Mechanism::LgcStatic, Mechanism::FedAvg, Mechanism::LgcDrl] {
        let mut cfg = base_cfg(mech, 8);
        cfg.downlink = Some(false);
        let reference = reference_log(base_cfg(mech, 8));
        let engine = engine_log(cfg);
        assert_logs_bitwise_equal(&reference, &engine, &format!("downlink-off {}", mech.name()));
        // And the new CSV columns stay at their inert zeros.
        for r in &engine.records {
            assert_eq!(r.down_bytes, 0);
            assert_eq!(r.down_energy_j, 0.0);
            assert_eq!(r.down_money, 0.0);
            assert_eq!(r.staleness_p50, 0.0);
            assert_eq!(r.staleness_p95, 0.0);
        }
    }
}

/// Barrier + dense downlink: the broadcast is exact (devices converge like
/// the free-broadcast run) but no longer free — download bytes/energy/money
/// are charged and the round time includes the downlink completion.
#[test]
fn barrier_dense_downlink_charges_and_extends_rounds() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 12);
    cfg.downlink = Some(true); // dense fallback compression
    let free = engine_log(base_cfg(Mechanism::LgcStatic, 12));
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let paid = exp.run(&mut trainer).unwrap();
    assert_eq!(paid.records.len(), 12);
    let nparams = trainer.nparams() as u64;
    for r in &paid.records {
        // Every device that uploaded got a dense delta back: 4 B/param.
        assert_eq!(r.down_bytes, 4 * nparams * r.completed);
        assert!(r.down_energy_j > 0.0 && r.down_money > 0.0);
        assert_eq!(r.staleness_p95, 0.0, "barrier sync is never stale");
    }
    // Meters carry the download split and the budget sees it.
    for dev in &exp.devices {
        assert!(dev.meter.down_energy_used > 0.0);
        assert!(dev.meter.energy_used >= dev.meter.down_energy_used);
        assert_eq!(dev.sync_state.pending_layers, 0, "round ends fully confirmed");
        assert_eq!(dev.sync_state.synced_round, 11);
    }
    // The downlink costs wall time: total simulated time strictly grows.
    assert!(
        paid.last().unwrap().total_time_s > free.last().unwrap().total_time_s,
        "paid {} <= free {}",
        paid.last().unwrap().total_time_s,
        free.last().unwrap().total_time_s
    );
    // Dense broadcast is exact, so training still converges normally.
    assert!(paid.final_acc() > 0.5, "acc={}", paid.final_acc());
}

/// The acceptance scenario: fast uplink, Bad-fading 3G downlink (the
/// asymmetric link), semi-async server. The downlink delay keeps devices
/// training on stale models — nonzero `staleness_p95` — and the download
/// energy/money count toward `Budget` early stop.
#[test]
fn asymmetric_downlink_reports_staleness_and_budget_counts_downloads() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 30);
    cfg.mechanism = Mechanism::parse("lgc-downlink").unwrap(); // layered downlink
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg.clone(), &trainer);
    // Asymmetry: every device's downlink pinned to Bad-fading 3G links.
    let dl = exp.downlink.as_mut().expect("preset enables downlink");
    for i in 0..3 {
        for link in dl.links_mut(i).links.iter_mut() {
            link.ty = ChannelType::G3;
            link.fading = Fading::Bad;
        }
    }
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 30);
    let down_bytes: u64 = log.records.iter().map(|r| r.down_bytes).sum();
    let down_energy: f64 = log.records.iter().map(|r| r.down_energy_j).sum();
    assert!(down_bytes > 0 && down_energy > 0.0);
    let max_p95 = log
        .records
        .iter()
        .map(|r| r.staleness_p95)
        .filter(|v| !v.is_nan())
        .fold(0.0f64, f64::max);
    assert!(
        max_p95 > 0.0,
        "slow downlink must leave devices training on stale models"
    );
    for r in &log.records {
        if !r.staleness_p50.is_nan() {
            assert!(r.staleness_p95 >= r.staleness_p50);
        }
    }
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());

    // Budget enforcement counts the downloads: an energy budget sized so
    // that uplink-only training survives longer must stop earlier once the
    // same budget also pays for (expensive, Bad-3G) downloads.
    let total_down_energy: f64 =
        exp.devices.iter().map(|d| d.meter.down_energy_used).sum();
    assert!(total_down_energy > 0.0);
    let per_dev_energy = exp.devices[0].meter.energy_used;
    let mut tight = cfg.clone();
    tight.energy_budget = per_dev_energy * 0.4;
    let mut tr2 = NativeLrTrainer::new(&tight);
    let mut exp2 = Experiment::new(tight.clone(), &tr2);
    let dl2 = exp2.downlink.as_mut().unwrap();
    for i in 0..3 {
        for link in dl2.links_mut(i).links.iter_mut() {
            link.ty = ChannelType::G3;
            link.fading = Fading::Bad;
        }
    }
    let short = exp2.run(&mut tr2).unwrap();
    assert!(
        short.records.len() < 30,
        "downlink charges should exhaust the budget early, ran {}",
        short.records.len()
    );
    let mut no_dl = tight;
    no_dl.downlink = Some(false);
    let mut tr3 = NativeLrTrainer::new(&no_dl);
    let mut exp3 = Experiment::new(no_dl, &tr3);
    let free = exp3.run(&mut tr3).unwrap();
    assert!(
        free.records.len() >= short.records.len(),
        "the same budget without download charges must last at least as long \
         ({} vs {})",
        free.records.len(),
        short.records.len()
    );
}

// ---------------------------------------------------------------------------
// Scenario subsystem: oracle equality when off (and trivial), handoff &
// trace-replay acceptance
// ---------------------------------------------------------------------------

/// A single-zone scenario with the default fading parameters, no mobility
/// and no phases — the seam's zero-cost claim made literal: the engine
/// output is bit-for-bit the frozen `step_round` oracle even with the
/// scenario machinery switched on.
fn trivial_scenario() -> ScenarioSpec {
    ScenarioSpec {
        name: "trivial".into(),
        move_prob: 0.0,
        start_spread: false,
        trace_len: 16,
        zones: vec![ZoneSpec {
            name: "world".into(),
            channels: vec![ChannelType::G5, ChannelType::G4, ChannelType::G3],
            bw_scale: 1.0,
            fading: Default::default(),
            dynamics: DynamicsKind::Markov,
        }],
        phases: Vec::new(),
        noma: false,
    }
}

/// The tentpole's hard constraint, both halves: with no scenario configured
/// every engine is the frozen oracle (covered throughout this file), and a
/// *trivial* scenario — same world, expressed through the seam — is bitwise
/// identical too, proving the seam itself costs nothing.
#[test]
fn trivial_scenario_stays_bitwise_on_oracle() {
    for mech in [Mechanism::LgcStatic, Mechanism::FedAvg] {
        let reference = reference_log(base_cfg(mech, 10));
        let mut cfg = base_cfg(mech, 10);
        cfg.scenario = Some(trivial_scenario());
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        assert!(exp.scenario.is_some());
        let engine = exp.run(&mut trainer).unwrap();
        assert_logs_bitwise_equal(&reference, &engine, &format!("trivial {}", mech.name()));
        for r in &engine.records {
            assert_eq!(r.handoffs, 0);
            assert_eq!(r.dropped_handoff, 0);
            assert_eq!(r.zone_p50, 0.0);
        }
    }
}

/// The acceptance scenario: `stadium-flash-crowd` under a seeded semi-async
/// run — the flash-crowd phase forces every device into the 5G/4G stadium
/// zone, stranding in-flight 3G enhancement layers. The run must record
/// nonzero `handoffs` *and* nonzero `dropped_handoff`, and still complete
/// every round.
#[test]
fn stadium_flash_crowd_semi_async_records_handoffs_and_drops() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 60);
    cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").unwrap());
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 60, "run completes under the scenario");
    let handoffs: u64 = log.records.iter().map(|r| r.handoffs).sum();
    let dropped: u64 = log.records.iter().map(|r| r.dropped_handoff).sum();
    assert!(handoffs > 0, "flash crowd must hand devices off");
    assert!(
        dropped > 0,
        "handoffs into the 3G-less stadium must strand in-flight layers \
         ({handoffs} handoffs, {dropped} drops)"
    );
    assert_eq!(exp.sim_stats.handoffs, handoffs);
    assert_eq!(exp.sim_stats.dropped_handoff, dropped);
    // The forced relocation shows in the mobility telemetry.
    assert!(
        log.records.iter().any(|r| r.zone_p50 > 0.0),
        "zone_p50 should reflect the crowd in the stadium"
    );
    // Dropped mass was restituted, not destroyed: training still works.
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    // Determinism: the same seed replays the same world.
    let mut cfg2 = base_cfg(Mechanism::LgcStatic, 60);
    cfg2.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").unwrap());
    cfg2.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    let mut trainer2 = NativeLrTrainer::new(&cfg2);
    let mut exp2 = Experiment::new(cfg2, &trainer2);
    let log2 = exp2.run(&mut trainer2).unwrap();
    assert_logs_bitwise_equal(&log, &log2, "stadium determinism");
}

/// `rural-3g` masks the device down to a single harsh 3G channel: the
/// static 3-layer plan is projected onto it (budget preserved), traffic
/// flows only there, and training still converges.
#[test]
fn rural_3g_preset_masks_channels_and_trains() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 14);
    cfg.scenario = Some(ScenarioRegistry::resolve("rural-3g").unwrap());
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    // The initial zone configuration applies at build, before any round.
    for dev in &exp.devices {
        assert_eq!(dev.channels.up_mask(), vec![false, false, true]);
    }
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 14);
    assert!(log.records.iter().all(|r| r.bytes_up > 0), "traffic still flows");
    for dev in &exp.devices {
        assert_eq!(dev.channels.up_mask(), vec![false, false, true], "3G only");
        assert_eq!(dev.channels.first_up(), Some(2));
        assert_eq!(dev.channels.links[0].effective_bandwidth(), 0.0);
    }
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    // Single zone, nobody moves: handoff-free world.
    assert!(log.records.iter().all(|r| r.handoffs == 0 && r.zone_p50 == 0.0));
}

/// The diurnal trace preset drives bandwidth (and thus round times) on a
/// deterministic day/night curve: seeded runs replay bitwise, and the
/// round-time series differs from the static Markov world.
#[test]
fn diurnal_trace_scenario_is_deterministic_and_shifts_round_times() {
    let run_diurnal = || {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 12);
        cfg.scenario = Some(ScenarioRegistry::resolve("diurnal").unwrap());
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        exp.run(&mut trainer).unwrap()
    };
    let a = run_diurnal();
    let b = run_diurnal();
    assert_logs_bitwise_equal(&a, &b, "diurnal determinism");
    let plain = engine_log(base_cfg(Mechanism::LgcStatic, 12));
    assert!(
        a.records
            .iter()
            .zip(&plain.records)
            .any(|(x, y)| x.round_time_s.to_bits() != y.round_time_s.to_bits()),
        "trace-driven bandwidth must change the timing profile"
    );
    assert!(a.final_acc() > 0.4, "acc={}", a.final_acc());
}

/// Scenario + population cohort engines: mobility and handoff run over the
/// whole (mostly demobilized) population, clients wake up in their current
/// zone, and the run completes.
#[test]
fn scenario_with_population_cohort_completes() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 20);
    cfg.population = Some(12);
    cfg.cohort = Some(4);
    cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").unwrap());
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 20);
    let handoffs: u64 = log.records.iter().map(|r| r.handoffs).sum();
    assert!(handoffs > 0, "population-wide mobility must hand off");
    assert!(log.records.iter().any(|r| r.zone_p50 > 0.0));
    let pop = exp.population.as_ref().unwrap();
    assert!(pop.peak_materialized() <= 4, "cohort memory bound holds");
}

/// Layered downlink under barrier sync: partial broadcasts leave devices
/// off the exact global, but the mirror-delta encoding is self-correcting,
/// so training still converges while paying layered (not dense) bytes.
#[test]
fn barrier_layered_downlink_trains_with_partial_broadcasts() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 20);
    cfg.mechanism = Mechanism::parse("lgc-downlink").unwrap();
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 20);
    let nparams = trainer.nparams() as u64;
    for r in &log.records {
        assert!(r.down_bytes > 0);
        assert!(
            r.down_bytes < 4 * nparams * r.completed,
            "layered broadcast must ship less than the dense model"
        );
    }
    // Devices are *not* bitwise at the global (partial sync)...
    let any_gap = exp.devices.iter().any(|d| {
        d.params_sync
            .iter()
            .zip(&exp.server.params)
            .any(|(a, b)| a.to_bits() != b.to_bits())
    });
    assert!(any_gap, "layered downlink should leave a partial-sync gap");
    // ...yet learning still happens.
    assert!(log.final_acc() > 0.5, "acc={}", log.final_acc());
}

// ---------------------------------------------------------------------------
// Hierarchical edge tier
// ---------------------------------------------------------------------------

/// The edge tier's hard constraint: with the tier disabled (explicitly or by
/// default) every engine stays bit-for-bit on the frozen oracle, and the new
/// edge telemetry columns are identically zero.
#[test]
fn edge_disabled_stays_bitwise_on_oracle() {
    for mech in [Mechanism::LgcStatic, Mechanism::FedAvg] {
        let reference = reference_log(base_cfg(mech, 10));
        let mut cfg = base_cfg(mech, 10);
        cfg.edge = Some(false);
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        assert!(exp.edge.is_none(), "edge=false must not build the tier");
        let engine = exp.run(&mut trainer).unwrap();
        assert_logs_bitwise_equal(&reference, &engine, &format!("edge-off {}", mech.name()));
        for r in &engine.records {
            assert_eq!(r.backhaul_bytes, 0);
            assert_eq!(r.backhaul_p95_s, 0.0);
            assert_eq!(r.migrated_handoff, 0);
            assert_eq!(r.edge_rounds_bound, 0);
        }
        assert_eq!(exp.sim_stats.migrated_handoff, 0);
    }
}

fn stadium_edge_cfg() -> ExperimentConfig {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 60);
    cfg.devices = 6;
    cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").unwrap());
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    // A starved 3G backhaul (x0.02) makes each ~31 KB partial-aggregate
    // frame cost seconds, so rounds go backhaul-bound; flush_k above the
    // fleet size keeps contributions held at the edge until the fleet
    // parks, so handoffs catch them mid-hold and must migrate them.
    cfg.edge_settings = Some(EdgeSettings {
        backhaul: ChannelType::G3,
        bw_scale: 0.02,
        flush_k: 8,
        ..EdgeSettings::default()
    });
    cfg
}

/// The acceptance scenario for the edge tier: `stadium-flash-crowd` under
/// semi-async with a throttled 3G backhaul. The run must pin deterministic
/// nonzero `migrated_handoff` (held contributions follow their device
/// through handoff) and at least one backhaul-bound round (the partial
/// aggregate's p95 backhaul wall exceeds the access-tier finish p95).
#[test]
fn stadium_flash_crowd_edge_migrates_and_goes_backhaul_bound() {
    let cfg = stadium_edge_cfg();
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert!(exp.edge.is_some(), "[edge] settings alone must enable the tier");
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 60, "run completes under the edge tier");
    let migrated: u64 = log.records.iter().map(|r| r.migrated_handoff).sum();
    assert!(migrated > 0, "flash-crowd handoffs must migrate held contributions");
    assert_eq!(exp.sim_stats.migrated_handoff, migrated);
    let backhaul: u64 = log.records.iter().map(|r| r.backhaul_bytes).sum();
    assert!(backhaul > 0, "partial aggregates must cross the backhaul");
    let bound: u64 = log.records.iter().map(|r| r.edge_rounds_bound).sum();
    assert!(
        bound >= 1,
        "starved backhaul must bound at least one round \
         (backhaul={backhaul} B, migrated={migrated})"
    );
    assert!(log.records.iter().any(|r| r.backhaul_p95_s > 0.0));
    // Restitution-free migration keeps the mass in play: training works.
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    // Determinism: the same seed replays the same two-tier world.
    let cfg2 = stadium_edge_cfg();
    let mut trainer2 = NativeLrTrainer::new(&cfg2);
    let mut exp2 = Experiment::new(cfg2, &trainer2);
    let log2 = exp2.run(&mut trainer2).unwrap();
    assert_logs_bitwise_equal(&log, &log2, "edge stadium determinism");
    for (x, y) in log.records.iter().zip(&log2.records) {
        assert_eq!(x.migrated_handoff, y.migrated_handoff, "round {}", x.round);
        assert_eq!(x.backhaul_bytes, y.backhaul_bytes, "round {}", x.round);
        assert_eq!(
            x.backhaul_p95_s.to_bits(),
            y.backhaul_p95_s.to_bits(),
            "round {}",
            x.round
        );
    }
}

/// Under barrier sync the edge tier only re-times the round — the cloud
/// aggregates the exact same updates in the same order — so a rural-3g run
/// over a throttled backhaul must finish strictly later in simulated time
/// than the flat topology while landing on bitwise-identical accuracy.
#[test]
fn rural_3g_throttled_backhaul_is_slower_at_equal_accuracy() {
    let flat = {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 14);
        cfg.scenario = Some(ScenarioRegistry::resolve("rural-3g").unwrap());
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        exp.run(&mut trainer).unwrap()
    };
    let edge = {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 14);
        cfg.scenario = Some(ScenarioRegistry::resolve("rural-3g").unwrap());
        cfg.edge_settings = Some(EdgeSettings {
            backhaul: ChannelType::G3,
            bw_scale: 0.05,
            flush_k: 2,
            ..EdgeSettings::default()
        });
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        exp.run(&mut trainer).unwrap()
    };
    assert_eq!(flat.records.len(), edge.records.len());
    for (f, e) in flat.records.iter().zip(&edge.records) {
        // Same model trajectory, bit for bit...
        assert_eq!(f.train_loss.to_bits(), e.train_loss.to_bits(), "round {}", f.round);
        if !(f.eval_acc.is_nan() && e.eval_acc.is_nan()) {
            assert_eq!(f.eval_acc.to_bits(), e.eval_acc.to_bits(), "round {}", f.round);
        }
        assert_eq!(f.bytes_up, e.bytes_up, "round {}", f.round);
        assert!(e.backhaul_bytes > 0, "round {}", f.round);
    }
    assert_eq!(flat.final_acc().to_bits(), edge.final_acc().to_bits());
    // ...paid for with strictly more simulated wall time.
    let t_flat = flat.records.last().unwrap().total_time_s;
    let t_edge = edge.records.last().unwrap().total_time_s;
    assert!(
        t_edge > t_flat,
        "throttled backhaul must slow the run: edge {t_edge} vs flat {t_flat}"
    );
}

/// The `lgc-edge` registry preset is runnable end to end: it enables the
/// tier and semi-async buffering by default, and the run label carries the
/// `+edge` seam.
#[test]
fn lgc_edge_preset_runs_end_to_end() {
    let mut cfg = base_cfg(Mechanism::parse("lgc-edge").unwrap(), 12);
    cfg.scenario = Some(trivial_scenario());
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert!(exp.edge.is_some());
    assert!(exp.run_label().contains("+edge"), "label {}", exp.run_label());
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 12);
    assert!(log.records.iter().map(|r| r.backhaul_bytes).sum::<u64>() > 0);
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
}
