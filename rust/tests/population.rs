//! Population-mode integration tests: the `FullParticipation` cohort engine
//! against the frozen `Experiment::step_round` oracle (bit for bit), the
//! O(model + cohort) memory bound at 100k clients, sampler determinism,
//! availability churn, and streaming-vs-batch aggregation tolerance.

use lgc::compression::{lgc_compress, CompressScratch, LgcUpdate};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, ExperimentBuilder, NativeLrTrainer, Server};
use lgc::metrics::RunLog;
use lgc::population::SamplerKind;
use lgc::sim::SyncMode;
use lgc::util::Rng;

fn base_cfg(mechanism: Mechanism, rounds: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        mechanism,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 3,
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        seed,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

/// The same experiment, switched into population mode with full
/// participation over a population the size of the device fleet — the
/// configuration the equivalence oracle freezes.
fn full_participation_cfg(mechanism: Mechanism, rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = base_cfg(mechanism, rounds, seed);
    cfg.population = Some(cfg.devices);
    cfg.cohort = Some(cfg.devices);
    cfg.sampler = Some(SamplerKind::Full);
    cfg
}

/// The frozen reference: the pre-engine synchronous loop, stepped by hand.
fn reference_log(cfg: ExperimentConfig) -> RunLog {
    let rounds = cfg.rounds;
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let mut log = RunLog::new("reference");
    for round in 0..rounds {
        match exp.step_round(round, &mut trainer).unwrap() {
            Some(rec) => log.push(rec),
            None => break,
        }
    }
    log
}

fn population_run(cfg: ExperimentConfig) -> (RunLog, Experiment) {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    assert!(exp.population.is_some(), "population mode expected");
    assert!(exp.devices.is_empty(), "no permanently materialized fleet");
    let log = exp.run(&mut trainer).unwrap();
    (log, exp)
}

fn assert_logs_bitwise_equal(a: &RunLog, b: &RunLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label} round {r}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} loss round {r}");
        assert_eq!(x.bytes_up, y.bytes_up, "{label} bytes round {r}");
        assert_eq!(
            x.round_time_s.to_bits(),
            y.round_time_s.to_bits(),
            "{label} round_time round {r}"
        );
        assert_eq!(
            x.total_time_s.to_bits(),
            y.total_time_s.to_bits(),
            "{label} total_time round {r}"
        );
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{label} energy round {r}");
        assert_eq!(x.money.to_bits(), y.money.to_bits(), "{label} money round {r}");
        if x.eval_acc.is_nan() || y.eval_acc.is_nan() {
            assert_eq!(x.eval_acc.is_nan(), y.eval_acc.is_nan(), "{label} eval round {r}");
        } else {
            assert_eq!(x.eval_acc.to_bits(), y.eval_acc.to_bits(), "{label} acc round {r}");
        }
        assert_eq!(
            x.finish_p50_s.to_bits(),
            y.finish_p50_s.to_bits(),
            "{label} p50 round {r}"
        );
        assert_eq!(x.sampled, y.sampled, "{label} sampled round {r}");
        assert_eq!(x.completed, y.completed, "{label} completed round {r}");
        assert_eq!(x.dropped_offline, y.dropped_offline, "{label} dropped round {r}");
    }
}

/// Acceptance criterion: `FullParticipation` over a materialized population
/// + batch aggregation reproduces `Experiment::step_round` bit for bit,
/// across mechanism shapes (sparse LGC, dense FedAvg, packed QSGD, RandK's
/// per-device RNG streams, the DDPG-controlled mechanism) and seeds.
#[test]
fn full_participation_matches_step_round_oracle_bitwise() {
    for seed in [42u64, 1234] {
        for (mech, rounds) in [
            (Mechanism::LgcStatic, 12),
            (Mechanism::FedAvg, 8),
            (Mechanism::Qsgd, 8),
            (Mechanism::RandK, 8),
            (Mechanism::LgcDrl, 6),
        ] {
            let reference = reference_log(base_cfg(mech, rounds, seed));
            let (cohort, exp) = population_run(full_participation_cfg(mech, rounds, seed));
            assert_eq!(cohort.records.len(), rounds, "{} seed {seed}", mech.name());
            assert_logs_bitwise_equal(
                &reference,
                &cohort,
                &format!("{} seed {seed}", mech.name()),
            );
            let pop = exp.population.as_ref().unwrap();
            assert_eq!(pop.materialized(), 0, "everything demobilized after the run");
            assert!(pop.peak_materialized() <= pop.cohort());
        }
    }
}

/// Oracle equivalence also under a budget early-stop.
#[test]
fn full_participation_matches_oracle_under_budget_stop() {
    let mut legacy = base_cfg(Mechanism::LgcStatic, 30, 42);
    legacy.energy_budget = 160.0;
    let mut popcfg = full_participation_cfg(Mechanism::LgcStatic, 30, 42);
    popcfg.energy_budget = 160.0;
    let reference = reference_log(legacy);
    let (cohort, _) = population_run(popcfg);
    assert!(reference.records.len() < 30, "budget should bite");
    assert_logs_bitwise_equal(&reference, &cohort, "budget-stop");
}

/// Acceptance criterion: memory scales with the cohort, not the population.
/// A 100k-client run at cohort 64 completes with at most 64 devices
/// materialized at any instant and zero left resident afterwards —
/// unsampled clients never own dense model replicas.
#[test]
fn materialized_devices_bounded_by_cohort_at_100k_clients() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 3, 42);
    cfg.devices = 4;
    cfg.samples_per_device = 128;
    cfg.eval_samples = 128;
    cfg.population = Some(100_000);
    cfg.cohort = Some(64);
    cfg.sampler = Some(SamplerKind::UniformK);
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 3);
    for rec in &log.records {
        assert_eq!(rec.sampled, 64, "full cohort every round");
        assert_eq!(rec.completed, 64, "lossless barrier path delivers all");
    }
    let pop = exp.population.as_ref().unwrap();
    assert_eq!(pop.len(), 100_000);
    assert!(
        pop.peak_materialized() <= 64,
        "peak {} exceeds cohort",
        pop.peak_materialized()
    );
    assert_eq!(pop.materialized(), 0, "no dense replicas survive the run");
    // Persisted per-client state: only the sampled clients carry residuals,
    // and a residual never exceeds one dense model (4 B/coordinate).
    let sampled_max = 3 * 64usize;
    let mut with_residual = 0usize;
    for id in 0..pop.len() {
        if !pop.residual_is_empty(id) {
            with_residual += 1;
            assert!(
                pop.residual_bytes_of(id) <= 2 * 4 * 7850,
                "residual beyond compact bound"
            );
        }
    }
    assert!(with_residual <= sampled_max, "{with_residual} residuals");
}

/// Population runs are deterministic given the seed, and seed-sensitive.
#[test]
fn sampler_determinism_given_seed() {
    let mk = |seed: u64| {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 8, seed);
        cfg.devices = 4;
        cfg.population = Some(64);
        cfg.cohort = Some(8);
        cfg.sampler = Some(SamplerKind::UniformK);
        population_run(cfg).0
    };
    let (a, b, c) = (mk(42), mk(42), mk(7));
    assert_logs_bitwise_equal(&a, &b, "same-seed uniform-k");
    assert!(
        a.records
            .iter()
            .zip(&c.records)
            .any(|(x, y)| x.train_loss.to_bits() != y.train_loss.to_bits()),
        "different seed should sample different cohorts"
    );
}

/// Weighted sampling runs end to end and the weighted rule is exercised
/// through the registry-standard experiment path.
#[test]
fn weighted_sampler_cohort_trains() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 20, 42);
    cfg.devices = 4;
    cfg.dirichlet_alpha = 0.1; // strongly unequal shards
    cfg.population = Some(24);
    cfg.cohort = Some(6);
    cfg.sampler = Some(SamplerKind::WeightedBySamples);
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 20);
    assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    assert!(exp.population.as_ref().unwrap().peak_materialized() <= 6);
}

/// Availability churn: offline clients are never sampled, mid-upload drops
/// feed the restitution path and are counted per round, and training still
/// completes.
#[test]
fn availability_churn_drops_uploads_and_still_runs() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 14, 42);
    cfg.devices = 4;
    cfg.population = Some(40);
    cfg.cohort = Some(8);
    cfg.sampler = Some(SamplerKind::AvailabilityMarkov);
    cfg.churn_down = 0.35;
    cfg.churn_up = 0.5;
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 14);
    let dropped: u64 = log.records.iter().map(|r| r.dropped_offline).sum();
    assert!(dropped > 0, "0.35 mid-upload churn over 14x8 uploads must drop");
    for rec in &log.records {
        // Every client that ran either delivered or dropped mid-upload.
        assert!(rec.completed + rec.dropped_offline <= rec.sampled);
        assert!(rec.sampled <= 8);
    }
    let stats = exp.sim_stats;
    assert_eq!(
        stats.dropped_offline, dropped,
        "engine counter agrees with the per-round records"
    );
    // Dropped mass is restituted, not destroyed: residuals exist.
    assert!(exp.population.as_ref().unwrap().residual_bytes() > 0);
}

/// Acceptance criterion: streaming aggregation equals batch aggregation to
/// the documented float tolerance — exercised at the server level and end
/// to end through the cohort engine.
#[test]
fn streaming_aggregation_matches_batch_within_tolerance() {
    // Server-level: same uploads through both paths.
    let mut rng = Rng::new(5);
    let dim = 512;
    let ups: Vec<LgcUpdate> = (0..7)
        .map(|_| {
            let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            lgc_compress(&u, &[16, 48], &mut CompressScratch::default())
        })
        .collect();
    let refs: Vec<&LgcUpdate> = ups.iter().collect();
    let mut batch = Server::new(vec![0f32; dim]);
    batch.aggregate_and_apply(&refs);
    let mut stream = Server::new(vec![0f32; dim]);
    stream.stream_begin();
    for u in &ups {
        stream.stream_accumulate(u, 1.0);
    }
    assert!(stream.stream_apply());
    for i in 0..dim {
        assert!(
            (batch.params[i] - stream.params[i]).abs() < 1e-5,
            "at {i}: batch {} vs stream {}",
            batch.params[i],
            stream.params[i]
        );
    }

    // End to end: a streaming cohort run trains, and its first round (one
    // aggregation of identical local work) stays within tolerance of the
    // batch run's.
    let mk = |streaming: bool| {
        let mut cfg = base_cfg(Mechanism::LgcStatic, 25, 42);
        cfg.devices = 4;
        cfg.population = Some(12);
        cfg.cohort = Some(6);
        cfg.sampler = Some(SamplerKind::UniformK);
        cfg.streaming = streaming;
        population_run(cfg).0
    };
    let (with_stream, with_batch) = (mk(true), mk(false));
    assert_eq!(with_stream.records.len(), 25);
    assert!(
        (with_stream.records[0].train_loss - with_batch.records[0].train_loss).abs() < 1e-9,
        "round 0 local work is identical"
    );
    assert!(with_stream.final_acc() > 0.5, "acc={}", with_stream.final_acc());
    assert!(with_batch.final_acc() > 0.5, "acc={}", with_batch.final_acc());
}

/// The cohort engine also runs under the async sync modes: a semi-async
/// slot pool over a 300-client population keeps at most `cohort` devices
/// materialized and emits one record per aggregation.
#[test]
fn cohort_semi_async_bounds_materialization() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 10, 42);
    cfg.devices = 4;
    cfg.population = Some(300);
    cfg.cohort = Some(8);
    cfg.sampler = Some(SamplerKind::UniformK);
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 4 });
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 10);
    for w in log.records.windows(2) {
        assert!(w[1].total_time_s >= w[0].total_time_s);
        assert!(w[1].energy_j >= w[0].energy_j);
    }
    let pop = exp.population.as_ref().unwrap();
    assert!(pop.peak_materialized() <= 8, "peak {}", pop.peak_materialized());
    assert_eq!(pop.materialized(), 0);
    assert!(exp.sim_stats.events > 0);
}

/// Fully-async + streaming over a population: each completed upload is
/// applied on arrival through the streaming seam.
#[test]
fn cohort_fully_async_streaming_runs() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 12, 42);
    cfg.devices = 4;
    cfg.population = Some(100);
    cfg.cohort = Some(6);
    cfg.sampler = Some(SamplerKind::UniformK);
    cfg.sync_mode = Some(SyncMode::FullyAsync { staleness_decay: 0.8 });
    cfg.streaming = true;
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 12);
    assert!(exp.population.as_ref().unwrap().peak_materialized() <= 6);
}

/// The builder's sampler override switches on population mode and wins over
/// the config key.
#[test]
fn builder_sampler_override_enables_population_mode() {
    let cfg = base_cfg(Mechanism::LgcStatic, 4, 42);
    let trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(&trainer)
        .sampler(|_ctx| Box::new(lgc::population::FullParticipation::new()))
        .build()
        .unwrap();
    assert!(exp.population.is_some());
    let mut trainer2 = NativeLrTrainer::new(&exp.cfg);
    let log = exp.run(&mut trainer2).unwrap();
    assert_eq!(log.records.len(), 4);
}

/// Population mode and per-device sync gaps are incompatible concepts.
#[test]
fn population_mode_rejects_sync_gaps() {
    let cfg = full_participation_cfg(Mechanism::LgcStatic, 4, 42);
    let trainer = NativeLrTrainer::new(&cfg);
    let err = ExperimentBuilder::new(cfg)
        .trainer(&trainer)
        .sync_gaps(vec![1, 2, 3])
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("sync_gaps"));
}

/// `step_round` is the legacy fully-materialized loop; population-mode
/// experiments must run through the cohort engine.
#[test]
#[should_panic(expected = "population-mode")]
fn step_round_rejects_population_mode() {
    let cfg = full_participation_cfg(Mechanism::LgcStatic, 4, 42);
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let _ = exp.step_round(0, &mut trainer);
}

// ---------------------------------------------------------------------------
// Downlink (accounting-only fidelity in population mode)
// ---------------------------------------------------------------------------

/// With the downlink explicitly disabled, the cohort engines stay
/// bit-for-bit on the frozen oracle (the tentpole's hard constraint,
/// spelled out rather than relying on the default).
#[test]
fn cohort_downlink_disabled_stays_on_oracle() {
    for mech in [Mechanism::LgcStatic, Mechanism::FedAvg] {
        let reference = reference_log(base_cfg(mech, 8, 42));
        let mut cfg = full_participation_cfg(mech, 8, 42);
        cfg.downlink = Some(false);
        let (log, _) = population_run(cfg);
        assert_logs_bitwise_equal(&reference, &log, &format!("downlink-off {}", mech.name()));
        for r in &log.records {
            assert_eq!(r.down_bytes, 0);
            assert_eq!(r.down_energy_j, 0.0);
        }
    }
}

/// Cohort barrier engine with the downlink enabled: every synced client's
/// broadcast is charged (accounting-only fidelity — budget-determined
/// sizes), SyncState persists on the demobilized specs, and the download
/// spend counts toward the budget.
#[test]
fn cohort_downlink_charges_broadcasts_and_persists_sync_state() {
    let mut cfg = full_participation_cfg(Mechanism::LgcStatic, 8, 42);
    cfg.downlink = Some(true);
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 8);
    for r in &log.records {
        assert!(r.down_bytes > 0, "round {}: {:?}", r.round, r.down_bytes);
        assert!(r.down_energy_j > 0.0 && r.down_money > 0.0);
    }
    let pop = exp.population.as_ref().unwrap();
    for id in 0..pop.len() {
        assert!(pop.meter(id).down_energy_used > 0.0, "client {id}");
        assert_eq!(pop.sync_state(id).synced_round, 7, "client {id}");
        assert_eq!(pop.sync_state(id).pending_layers, 0, "client {id}");
    }
    // Free-broadcast run under the same budget lasts at least as long.
    let mut tight = full_participation_cfg(Mechanism::LgcStatic, 40, 42);
    tight.downlink = Some(true);
    tight.energy_budget = pop.meter(0).energy_used * 1.5;
    let (short, _) = population_run(tight.clone());
    let mut free = tight;
    free.downlink = Some(false);
    let (long, _) = population_run(free);
    assert!(
        long.records.len() >= short.records.len(),
        "download charges must not extend the budgeted run ({} vs {})",
        long.records.len(),
        short.records.len()
    );
    assert!(short.records.len() < 40, "budget should bite");
}

/// Cohort async engine with the downlink: broadcasts ride SyncConfirmed
/// events, so the run still completes, charges downloads, and keeps the
/// materialization bound.
#[test]
fn cohort_async_downlink_runs_and_charges() {
    let mut cfg = base_cfg(Mechanism::LgcStatic, 16, 42);
    cfg.population = Some(8);
    cfg.cohort = Some(3);
    cfg.sampler = Some(SamplerKind::UniformK);
    cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
    cfg.downlink = Some(true);
    let (log, exp) = population_run(cfg);
    assert_eq!(log.records.len(), 16);
    let down: u64 = log.records.iter().map(|r| r.down_bytes).sum();
    assert!(down > 0);
    let pop = exp.population.as_ref().unwrap();
    assert!(pop.peak_materialized() <= 3, "bound: {}", pop.peak_materialized());
    assert_eq!(pop.materialized(), 0, "everyone demobilized after the run");
    let (te, tm) = pop.meter_totals();
    assert!(te > 0.0 && tm > 0.0);
}
