//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-check numerics against the pure-Rust oracle. These tests skip
//! (with a notice) when the `pjrt` feature is off or `artifacts/` has not
//! been built.

use std::path::Path;

use lgc::models::NativeLr;
use lgc::runtime::{BatchX, Runtime};
use lgc::util::Rng;

fn runtime() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.toml").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime init"))
}

fn batch(rng: &mut Rng, b: usize, feat: usize, nclass: usize) -> (Vec<f32>, Vec<i32>) {
    let x = (0..b * feat).map(|_| rng.uniform_f32()).collect();
    let y = (0..b).map(|_| rng.index(nclass) as i32).collect();
    (x, y)
}

#[test]
fn lr_grad_matches_native_oracle() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("lr").unwrap();
    let params = rt.load_init_params("lr").unwrap();
    let mut rng = Rng::new(1);
    let (x, y) = batch(&mut rng, 64, 784, 10);

    let (grads, loss) = exe.grad(&params, &BatchX::F32(x.clone()), &y).unwrap();
    let native = NativeLr::new();
    let mut ngrads = vec![0f32; params.len()];
    let nloss = native.loss_grad(&params, &x, &y, &mut ngrads);

    assert!((loss - nloss).abs() < 1e-4, "loss: pjrt {loss} vs native {nloss}");
    let mut max_err = 0f32;
    for (g, n) in grads.iter().zip(&ngrads) {
        max_err = max_err.max((g - n).abs());
    }
    assert!(max_err < 1e-4, "max grad err {max_err}");
}

#[test]
fn lr_local_step_applies_sgd_via_pallas_kernel() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("lr").unwrap();
    let params0 = rt.load_init_params("lr").unwrap();
    let mut rng = Rng::new(2);
    let (x, y) = batch(&mut rng, 64, 784, 10);
    let lr = 0.05f32;

    // local = grad + p - lr*g composition
    let (grads, _) = exe.grad(&params0, &BatchX::F32(x.clone()), &y).unwrap();
    let mut params = params0.clone();
    let loss = exe.local_step(&mut params, &BatchX::F32(x), &y, lr).unwrap();
    assert!(loss.is_finite());
    let mut max_err = 0f32;
    for i in 0..params.len() {
        let expect = params0[i] - lr * grads[i];
        max_err = max_err.max((params[i] - expect).abs());
    }
    assert!(max_err < 1e-5, "max param err {max_err}");
}

#[test]
fn lr_eval_counts_match_native() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("lr").unwrap();
    let params = rt.load_init_params("lr").unwrap();
    let mut rng = Rng::new(3);
    let (x, y) = batch(&mut rng, 64, 784, 10);
    let (loss_sum, correct) = exe.eval_batch(&params, &BatchX::F32(x.clone()), &y).unwrap();
    let native = NativeLr::new();
    let (nls, nc) = native.eval(&params, &x, &y);
    assert!((loss_sum - nls).abs() < 1e-3, "{loss_sum} vs {nls}");
    assert_eq!(correct, nc);
}

#[test]
fn cnn_local_steps_decrease_loss() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("cnn").unwrap();
    let mut params = rt.load_init_params("cnn").unwrap();
    let mut rng = Rng::new(4);
    let (x, y) = batch(&mut rng, 64, 784, 10);
    let bx = BatchX::F32(x);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for i in 0..6 {
        let loss = exe.local_step(&mut params, &bx, &y, 0.05).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "cnn loss {first} -> {last}");
}

#[test]
fn rnn_local_steps_decrease_loss() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("rnn").unwrap();
    let mut params = rt.load_init_params("rnn").unwrap();
    let mut rng = Rng::new(5);
    // narrow slice of corpus -> predictable -> loss should fall fast
    let corpus = lgc::data::CharCorpus::embedded(rt.manifest.seq);
    let mut buf = Vec::new();
    corpus.fill_batch(&mut rng, (0, 200), 64, &mut buf);
    let bx = BatchX::I32(buf);
    let y = vec![0i32; 64];
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for i in 0..8 {
        let loss = exe.local_step(&mut params, &bx, &y, 0.5).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "rnn loss {first} -> {last}");
    assert!(first < (64f64).ln() * 1.5, "init loss way off: {first}");
}

#[test]
fn compress_artifact_matches_rust_compressor() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_compress().unwrap();
    let d = exe.d;
    let ks = rt.manifest.compress_ks.clone();
    let mut rng = Rng::new(6);
    let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();

    let (layers, thr) = exe.compress(&u).unwrap();
    assert_eq!(layers.len(), ks.len() * d);
    assert_eq!(thr.len(), ks.len() + 1);

    // Dense decode from the artifact == rust-native lgc_compress decode.
    let mut dense = vec![0f32; d];
    for c in 0..ks.len() {
        for i in 0..d {
            dense[i] += layers[c * d + i];
        }
    }
    let mut scratch = lgc::compression::CompressScratch::default();
    let native = lgc::compression::lgc_compress(&u, &ks, &mut scratch);
    let ndense = native.decode();
    let nnz_a = dense.iter().filter(|&&x| x != 0.0).count();
    let nnz_b = ndense.iter().filter(|&&x| x != 0.0).count();
    assert_eq!(nnz_a, nnz_b, "support sizes differ");
    let mut diff = 0usize;
    for i in 0..d {
        if (dense[i] - ndense[i]).abs() > 1e-6 {
            diff += 1;
        }
    }
    assert_eq!(diff, 0, "{diff} coordinates differ between artifact and native");
}

#[test]
fn pjrt_full_lr_experiment_smoke() {
    let Some(rt) = runtime() else { return };
    use lgc::config::{ExperimentConfig, Mechanism, Workload};
    use lgc::coordinator::{Experiment, PjrtTrainer};
    let cfg = ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds: 6,
        devices: 2,
        samples_per_device: 128,
        eval_samples: 128,
        eval_every: 2,
        h_fixed: 2,
        h_max: 4,
        lr: 0.05,
        ..ExperimentConfig::default()
    };
    let mut trainer = PjrtTrainer::new(&rt, &cfg).unwrap();
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer).unwrap();
    assert_eq!(log.records.len(), 6);
    let first = log.records.first().unwrap().train_loss;
    let last = log.records.last().unwrap().train_loss;
    assert!(last < first, "{first} -> {last}");
}
