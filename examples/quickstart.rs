//! Quickstart: train logistic regression with LGC over 3 simulated edge
//! devices x 3 channels (5G/4G/3G), comparing mechanisms end-to-end through
//! [`ExperimentBuilder`] — in under a minute on the native path, no
//! artifacts needed.
//!
//! Also demonstrates the extension seams: the last run swaps in the
//! `DenseNoop` reference compressor and sample-weighted aggregation with
//! two builder calls (see DESIGN.md §"Extension points").
//!
//! ```bash
//! cargo run --release --example quickstart
//! # or with the PJRT artifacts (after `make artifacts`):
//! LGC_USE_RUNTIME=1 cargo run --release --example quickstart
//! ```

use lgc::compression::DenseNoop;
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{
    ExperimentBuilder, LocalTrainer, NativeLrTrainer, PjrtTrainer, WeightedBySamples,
};
use lgc::metrics::RunLog;
use lgc::runtime::Runtime;
use lgc::sim::SyncMode;

fn base_cfg(use_runtime: bool) -> ExperimentConfig {
    ExperimentConfig {
        workload: Workload::LrMnist,
        rounds: 40,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 512,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 6,
        use_runtime,
        ..ExperimentConfig::default()
    }
}

fn make_trainer(cfg: &ExperimentConfig) -> anyhow::Result<Box<dyn LocalTrainer>> {
    if cfg.use_runtime {
        let rt = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
        Ok(Box::new(PjrtTrainer::new(&rt, cfg)?))
    } else {
        Ok(Box::new(NativeLrTrainer::new(cfg)))
    }
}

fn report(name: &str, log: &RunLog) {
    let last = log.last().unwrap();
    let mb: f64 =
        log.records.iter().map(|r| r.bytes_up).sum::<u64>() as f64 / (1024.0 * 1024.0);
    println!(
        "{:<22} {:>8} {:>10.4} {:>12.1} {:>10.4} {:>10.3}",
        name,
        log.records.len(),
        log.final_acc(),
        last.energy_j,
        last.money,
        mb
    );
}

fn main() -> anyhow::Result<()> {
    let use_runtime = std::env::var("LGC_USE_RUNTIME").is_ok();
    println!(
        "LGC quickstart — {} path\n",
        if use_runtime { "PJRT artifact" } else { "native LR" }
    );
    println!(
        "{:<22} {:>8} {:>10} {:>12} {:>10} {:>10}",
        "mechanism", "rounds", "final acc", "energy (J)", "money", "MB sent"
    );

    // Registry presets: one builder line per mechanism.
    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::LgcDrl] {
        let mut cfg = base_cfg(use_runtime);
        cfg.mechanism = mech;
        let mut trainer = make_trainer(&cfg)?;
        let mut exp = ExperimentBuilder::new(cfg).trainer(trainer.as_ref()).build()?;
        let log = exp.run(trainer.as_mut())?;
        report(mech.name(), &log);
    }

    // Custom seams: dense reference compressor + sample-weighted mean,
    // plugged in without touching any mechanism code.
    let mut cfg = base_cfg(use_runtime);
    cfg.mechanism = Mechanism::FedAvg;
    let mut trainer = make_trainer(&cfg)?;
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(trainer.as_ref())
        .compressor(|_ctx, _id| Box::new(DenseNoop))
        .aggregator(|_ctx| Box::new(WeightedBySamples::new()))
        .build()?;
    let log = exp.run(trainer.as_mut())?;
    report("dense+weighted (custom)", &log);

    // Sync-mode seam: the same mechanism under FedBuff-style semi-async
    // aggregation on the event engine — the server aggregates every 2
    // completed uploads instead of waiting for the slowest device.
    let mut cfg = base_cfg(use_runtime);
    cfg.mechanism = Mechanism::LgcStatic;
    let mut trainer = make_trainer(&cfg)?;
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(trainer.as_ref())
        .sync_mode(SyncMode::SemiAsync { buffer_k: 2 })
        .build()?;
    let log = exp.run(trainer.as_mut())?;
    report("lgc-static semi-async", &log);

    println!("\nLGC matches FedAvg accuracy at a fraction of the bytes/energy —");
    println!("see benches/ for the full Figure 3/4/5/6 reproductions, and");
    println!("EXPERIMENTS.md for async/straggler scenario recipes.");
    Ok(())
}
