//! Quickstart: train logistic regression with LGC over 3 simulated edge
//! devices x 3 channels (5G/4G/3G), comparing against FedAvg — in under a
//! minute on the native path, no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! # or with the PJRT artifacts (after `make artifacts`):
//! LGC_USE_RUNTIME=1 cargo run --release --example quickstart
//! ```

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, LocalTrainer, NativeLrTrainer, PjrtTrainer};
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let use_runtime = std::env::var("LGC_USE_RUNTIME").is_ok();
    let mut cfg = ExperimentConfig {
        workload: Workload::LrMnist,
        rounds: 40,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 512,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 6,
        use_runtime,
        ..ExperimentConfig::default()
    };

    println!("LGC quickstart — {} path\n", if use_runtime { "PJRT artifact" } else { "native LR" });
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>10} {:>10}",
        "mechanism", "rounds", "final acc", "energy (J)", "money", "MB sent"
    );

    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::LgcDrl] {
        cfg.mechanism = mech;
        let mut trainer: Box<dyn LocalTrainer> = if use_runtime {
            let rt = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
            Box::new(PjrtTrainer::new(&rt, &cfg)?)
        } else {
            Box::new(NativeLrTrainer::new(&cfg))
        };
        let mut exp = Experiment::new(cfg.clone(), trainer.as_ref());
        let log = exp.run(trainer.as_mut())?;
        let last = log.last().unwrap();
        let mb: f64 =
            log.records.iter().map(|r| r.bytes_up).sum::<u64>() as f64 / (1024.0 * 1024.0);
        println!(
            "{:<12} {:>8} {:>10.4} {:>12.1} {:>10.4} {:>10.3}",
            mech.name(),
            log.records.len(),
            log.final_acc(),
            last.energy_j,
            last.money,
            mb
        );
    }
    println!("\nLGC matches FedAvg accuracy at a fraction of the bytes/energy —");
    println!("see benches/ for the full Figure 3/4/5/6 reproductions.");
    Ok(())
}
