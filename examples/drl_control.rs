//! Watch the DDPG controller learn (paper Fig. 5): runs LGC-DRL on the
//! native LR path and prints the per-episode critic loss and reward as the
//! agents discover cheap (H, D) policies. No artifacts needed.
//!
//! `cargo run --release --example drl_control [episodes]`

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};

fn main() -> anyhow::Result<()> {
    let episodes: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let rounds_per_episode = 25;

    let cfg = ExperimentConfig {
        mechanism: Mechanism::LgcDrl,
        workload: Workload::LrMnist,
        rounds: episodes * rounds_per_episode,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 256,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 8,
        use_runtime: false,
        ..ExperimentConfig::default()
    };
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg).trainer(&trainer).build()?;

    println!("episode  mean_reward  mean_energy_J/round  mean_H  eval_acc");
    for ep in 0..episodes {
        // fresh FL problem per episode; agents persist and keep learning
        exp.reset_episode(&trainer);
        let mut reward_acc = 0.0;
        let mut reward_n = 0usize;
        let mut acc = f64::NAN;
        for round in 0..rounds_per_episode {
            let Some(rec) = exp.step_round(round, &mut trainer)? else { break };
            if rec.drl_reward.is_finite() {
                reward_acc += rec.drl_reward;
                reward_n += 1;
            }
            if !rec.eval_acc.is_nan() {
                acc = rec.eval_acc;
            }
        }
        let energy1 = exp.devices.iter().map(|d| d.meter.energy_used).sum::<f64>();
        let mean_h: f64 = exp
            .agents
            .iter()
            .flatten()
            .map(|a| {
                // greedy H at a neutral state, as a readout of the policy
                let state = vec![0.1f32; a.ddpg.state_dim()];
                a.decode(&a.ddpg.act_greedy(&state)).local_steps as f64
            })
            .sum::<f64>()
            / exp.agents.len() as f64;
        println!(
            "{:>7}  {:>11.4}  {:>19.2}  {:>6.2}  {:>8.4}",
            ep,
            reward_acc / reward_n.max(1) as f64,
            energy1 / rounds_per_episode as f64, // meters reset per episode
            mean_h,
            acc
        );
    }
    println!("\nreward should trend upward as the agents learn cheaper policies");
    Ok(())
}
