//! The multi-channel tradeoff the paper motivates (Sec. 1): sending copies
//! on every channel is wasteful; one channel leaves capacity unused; LGC's
//! layered split uses all channels without redundancy.
//!
//! This example sweeps layer-to-channel strategies at a fixed coordinate
//! budget on the native LR path and reports time / energy / money / accuracy.

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};

fn run(name: &str, fracs: Vec<f64>, mech: Mechanism) -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        mechanism: mech,
        workload: Workload::LrMnist,
        rounds: 40,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 256,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 6,
        layer_fracs: fracs,
        use_runtime: false,
        ..ExperimentConfig::default()
    };
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg).trainer(&trainer).build()?;
    let log = exp.run(&mut trainer)?;
    let last = log.last().unwrap();
    let mb = log.records.iter().map(|r| r.bytes_up).sum::<u64>() as f64 / (1024.0 * 1024.0);
    println!(
        "{:<28} acc {:.4}   time {:>7.1}s   energy {:>9.1}J   money {:>7.4}   {:>7.3} MB",
        name,
        log.final_acc(),
        last.total_time_s,
        last.energy_j,
        last.money,
        mb
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("strategy                      (same 20% coordinate budget, 40 rounds)\n");
    // all coordinates through one channel (the fastest)
    run("single-channel top-k", vec![0.20], Mechanism::TopK)?;
    // layered across 3 channels, base layer on 5G
    run("LGC layered 1/4/15%", vec![0.01, 0.04, 0.15], Mechanism::LgcStatic)?;
    // balanced split
    run("LGC layered equal thirds", vec![0.066, 0.066, 0.068], Mechanism::LgcStatic)?;
    // DRL-adapted split
    run("LGC + DDPG control", vec![0.01, 0.04, 0.15], Mechanism::LgcDrl)?;
    println!("\nFedAvg reference (dense):");
    run("fedavg dense", vec![0.01], Mechanism::FedAvg)?;
    Ok(())
}
