//! **End-to-end driver** (the EXPERIMENTS.md §E2E run): train the CNN
//! (~207k params) on the MNIST-class workload through the FULL three-layer
//! stack — Rust coordinator -> PJRT -> AOT HLO containing the JAX fwd/bwd
//! and the Pallas fused-SGD kernel — for a few hundred rounds with LGC
//! compression and the DDPG controller, logging the loss curve.
//!
//! Requires artifacts: `make artifacts && cargo run --release --example
//! mnist_cnn_lgc [rounds] [mechanism]`.

use std::path::Path;

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, PjrtTrainer};
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let mechanism = args
        .get(1)
        .map(|s| Mechanism::parse(s).unwrap())
        .unwrap_or(Mechanism::LgcDrl);

    let cfg = ExperimentConfig {
        mechanism,
        workload: Workload::CnnMnist,
        rounds,
        devices: 3,
        samples_per_device: 2048,
        eval_samples: 512,
        eval_every: 10,
        lr: 0.05,
        h_fixed: 4,
        h_max: 8,
        ..ExperimentConfig::default()
    };

    let rt = Runtime::new(Path::new(&cfg.artifacts_dir))?;
    println!(
        "E2E: CNN ({} params) x {} devices x {} rounds, mechanism={}, platform={}",
        rt.manifest.models["cnn"].params,
        cfg.devices,
        cfg.rounds,
        cfg.mechanism.name(),
        rt.platform()
    );
    let mut trainer = PjrtTrainer::new(&rt, &cfg)?;
    let mut exp = ExperimentBuilder::new(cfg).trainer(&trainer).build()?;

    let t0 = std::time::Instant::now();
    let mut log = lgc::metrics::RunLog::new("e2e-cnn");
    for round in 0..exp.cfg.rounds {
        match exp.step_round(round, &mut trainer)? {
            Some(rec) => {
                if !rec.eval_acc.is_nan() {
                    println!(
                        "round {:>4}  train_loss {:.4}  eval_loss {:.4}  eval_acc {:.4}  energy {:>9.1} J  money {:.4}  sim_time {:>7.1}s  wall {:>6.1}s",
                        rec.round,
                        rec.train_loss,
                        rec.eval_loss,
                        rec.eval_acc,
                        rec.energy_j,
                        rec.money,
                        rec.total_time_s,
                        t0.elapsed().as_secs_f64()
                    );
                }
                log.push(rec);
            }
            None => {
                println!("all devices out of budget at round {round}");
                break;
            }
        }
    }
    let csv = Path::new("results/e2e_cnn.csv");
    log.write_csv(csv)?;
    println!(
        "\nfinal acc {:.4} (best {:.4}); loss curve written to {}",
        log.final_acc(),
        log.best_acc(),
        csv.display()
    );
    Ok(())
}
