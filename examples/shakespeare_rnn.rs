//! Char-GRU on the Shakespeare corpus (paper Fig. 6 workload) through the
//! PJRT artifacts, with LGC layered compression over three channels.
//!
//! `make artifacts && cargo run --release --example shakespeare_rnn [rounds]`

use std::path::Path;

use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, PjrtTrainer};
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let cfg = ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::RnnShakespeare,
        rounds,
        devices: 3,
        eval_samples: 256,
        eval_every: 5,
        lr: 0.5, // char-GRU with plain SGD wants a hot step size
        h_fixed: 2,
        h_max: 4,
        ..ExperimentConfig::default()
    };
    let rt = Runtime::new(Path::new(&cfg.artifacts_dir))?;
    println!(
        "RNN ({} params, vocab {}, seq {}) x {} devices x {} rounds",
        rt.manifest.models["rnn"].params,
        rt.manifest.vocab,
        rt.manifest.seq,
        cfg.devices,
        rounds
    );
    let mut trainer = PjrtTrainer::new(&rt, &cfg)?;
    let mut exp = ExperimentBuilder::new(cfg).trainer(&trainer).build()?;
    let mut log = lgc::metrics::RunLog::new("shakespeare-rnn");
    for round in 0..exp.cfg.rounds {
        let Some(rec) = exp.step_round(round, &mut trainer)? else { break };
        if !rec.eval_acc.is_nan() {
            println!(
                "round {:>4}  train_loss {:.4}  eval_loss {:.4}  next-char acc {:.4}",
                rec.round, rec.train_loss, rec.eval_loss, rec.eval_acc
            );
        }
        log.push(rec);
    }
    log.write_csv(Path::new("results/shakespeare_rnn.csv"))?;
    println!("final next-char accuracy: {:.4}", log.final_acc());
    Ok(())
}
