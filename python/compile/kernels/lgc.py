"""L1 — Pallas kernels for the LGC hot paths.

Three kernels, all elementwise/bandwidth-bound, all tiled with ``BlockSpec``
so each tile streams HBM->VMEM once:

- ``band_sparsify``:  the paper's Top_{alpha,beta} *apply* step (Eq. 1).
  Given the two magnitude thresholds of a layer band, keep ``x_i`` iff
  ``thr_hi >= |x_i| > thr_lo``.  Threshold *selection* (a global order
  statistic) lives in L2 (`lax.top_k`), mirroring the global-select /
  local-apply split of GPU top-k sparsifiers.
- ``ef_update``:      fused error-feedback memory update (Alg. 1 line 11):
  ``e' = u - g`` where ``u = e + w - w_hat`` and ``g`` is the shipped update.
- ``sgd_step``:       fused local SGD update ``p' = p - lr * g`` (Alg. 1
  line 6), called from every L2 local-step graph so it lowers into the same
  HLO the Rust runtime executes.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO ops that any backend
runs.  On a real TPU the same kernels compile as written; the BlockSpec
tiling below is the HBM<->VMEM schedule (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size for 1-D parameter/gradient streams.  8 * 128 = one float32
# (sublane, lane) VMEM tile on TPU; on CPU-interpret it is just the block
# length.  All public wrappers pad to a multiple of this.
TILE = 1024


def _pad_to_tile(x: jax.Array) -> tuple[jax.Array, int]:
    """Pad a 1-D array with zeros to a multiple of TILE. Returns (padded, n)."""
    n = x.shape[0]
    rem = (-n) % TILE
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,), x.dtype)])
    return x, n


# ---------------------------------------------------------------------------
# band_sparsify — Top_{alpha,beta} apply (paper Eq. 1)
# ---------------------------------------------------------------------------


def _band_kernel(thr_ref, x_ref, o_ref):
    """Keep x where thr_hi >= |x| > thr_lo; zero elsewhere.

    ``thr_ref`` is a 2-element SMEM-like block broadcast to every grid point:
    ``thr_ref[0] = thr_hi`` (the alpha-th largest magnitude),
    ``thr_ref[1] = thr_lo`` (the beta-th largest magnitude).
    """
    x = x_ref[...]
    a = jnp.abs(x)
    keep = jnp.logical_and(a <= thr_ref[0], a > thr_ref[1])
    o_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=())
def band_sparsify(x: jax.Array, thr_hi: jax.Array, thr_lo: jax.Array) -> jax.Array:
    """Pallas Top_{alpha,beta} band mask over a 1-D vector.

    ``thr_hi``/``thr_lo`` are scalars (0-d or 1-element arrays): the
    magnitudes of the alpha-th and beta-th largest |x|.  Elements with
    ``thr_hi >= |x| > thr_lo`` are kept.  ``thr_hi = +inf`` gives a plain
    Top_beta complement band; ``thr_lo = -inf``/0-with-care keeps ties.
    """
    xp, n = _pad_to_tile(x.astype(jnp.float32))
    thr = jnp.stack([jnp.asarray(thr_hi, jnp.float32).reshape(()),
                     jnp.asarray(thr_lo, jnp.float32).reshape(())])
    grid = (xp.shape[0] // TILE,)
    out = pl.pallas_call(
        _band_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),        # thresholds: broadcast
            pl.BlockSpec((TILE,), lambda i: (i,)),     # x: one tile per step
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(thr, xp)
    return out[:n]


# ---------------------------------------------------------------------------
# ef_update — fused error-feedback memory update (Alg. 1 line 11)
# ---------------------------------------------------------------------------


def _ef_kernel(u_ref, g_ref, o_ref):
    o_ref[...] = u_ref[...] - g_ref[...]


def ef_update(u: jax.Array, g: jax.Array) -> jax.Array:
    """e' = u - g, elementwise, tiled.  u is the error-compensated update
    (e + w - w_hat), g the compressed update actually shipped."""
    up, n = _pad_to_tile(u.astype(jnp.float32))
    gp, _ = _pad_to_tile(g.astype(jnp.float32))
    grid = (up.shape[0] // TILE,)
    out = pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(up.shape, jnp.float32),
        interpret=True,
    )(up, gp)
    return out[:n]


# ---------------------------------------------------------------------------
# sgd_step — fused p' = p - lr * g (Alg. 1 line 6)
# ---------------------------------------------------------------------------


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def sgd_step(params: jax.Array, grads: jax.Array, lr: jax.Array) -> jax.Array:
    """One fused SGD update over a flat f32[P] parameter vector."""
    pp, n = _pad_to_tile(params.astype(jnp.float32))
    gp, _ = _pad_to_tile(grads.astype(jnp.float32))
    lr1 = jnp.asarray(lr, jnp.float32).reshape((1,))
    grid = (pp.shape[0] // TILE,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),        # lr: broadcast scalar
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        interpret=True,
    )(lr1, pp, gp)
    return out[:n]


# ---------------------------------------------------------------------------
# lgc_layers — full LGC_k encoder (paper Eq. 2), C banded layers
# ---------------------------------------------------------------------------


def lgc_layers(u: jax.Array, ks: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """Split ``u`` into ``C = len(ks)`` magnitude-banded layers (Eq. 2).

    Layer ``c`` keeps the coordinates ranked ``(K_{c-1}, K_c]`` by |u|,
    with ``K_c = ks[0] + ... + ks[c]``.  Returns ``(layers, thresholds)``
    where ``layers`` is ``f32[C, D]`` (dense, zero off-band) and
    ``thresholds`` is ``f32[C + 1]`` (``+inf`` sentinel first).

    Threshold selection is a single global ``lax.top_k`` over |u| (L2);
    the per-layer banding is the Pallas ``band_sparsify`` kernel (L1).
    ``sum(layers, axis=0) == LGC_k(u)`` by the partition invariant.
    """
    d = u.shape[0]
    ktot = int(sum(ks))
    if not (0 < ktot <= d):
        raise ValueError(f"sum(ks)={ktot} out of range for D={d}")
    mags = jnp.abs(u.astype(jnp.float32))
    # Fetch one extra order statistic: Eq. 1's strict `> thr_beta` would drop
    # the K-th ranked element itself, so the bottom sentinel is the
    # (K+1)-th largest magnitude (or -1 when K == D, keeping everything).
    # NOTE: a full descending sort, not `lax.top_k` — top_k lowers to the
    # `topk(..., largest=true)` HLO op which xla_extension 0.5.1's text
    # parser rejects; `sort` round-trips cleanly.
    top_vals = -jnp.sort(-mags)
    cum = []
    acc = 0
    for k in ks:
        acc += int(k)
        cum.append(acc - 1)
    inner = top_vals[jnp.asarray(cum[:-1])] if len(ks) > 1 else jnp.zeros((0,), jnp.float32)
    bottom = top_vals[ktot] if ktot < d else jnp.float32(-1.0)
    thr = jnp.concatenate(
        [jnp.full((1,), jnp.inf, jnp.float32), inner, bottom.reshape((1,))]
    )
    layers = [band_sparsify(u, thr[c], thr[c + 1]) for c in range(len(ks))]
    return jnp.stack(layers), thr
