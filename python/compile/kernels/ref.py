"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in ``lgc.py`` has an exact reference here, written with plain
``jnp`` ops and no Pallas.  pytest + hypothesis assert ``assert_allclose``
between kernel and oracle across shapes and magnitudes (python/tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def band_sparsify_ref(x: jax.Array, thr_hi, thr_lo) -> jax.Array:
    """Eq. 1: keep x_i iff thr_hi >= |x_i| > thr_lo."""
    x = x.astype(jnp.float32)
    a = jnp.abs(x)
    keep = jnp.logical_and(a <= jnp.float32(thr_hi), a > jnp.float32(thr_lo))
    return jnp.where(keep, x, jnp.zeros_like(x))


def ef_update_ref(u: jax.Array, g: jax.Array) -> jax.Array:
    """Alg. 1 line 11: e' = u - g."""
    return u.astype(jnp.float32) - g.astype(jnp.float32)


def sgd_step_ref(params: jax.Array, grads: jax.Array, lr) -> jax.Array:
    """Alg. 1 line 6: p' = p - lr * g."""
    return params.astype(jnp.float32) - jnp.float32(lr) * grads.astype(jnp.float32)


def topk_ref(x: jax.Array, k: int) -> jax.Array:
    """Dense Top_k: zero all but the k largest-|.| coordinates."""
    x = x.astype(jnp.float32)
    d = x.shape[0]
    if k >= d:
        return x
    thr = (-jnp.sort(-jnp.abs(x)))[k]
    return jnp.where(jnp.abs(x) > thr, x, jnp.zeros_like(x))


def lgc_layers_ref(u: jax.Array, ks: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """Reference LGC_k encoder (Eq. 2) with the same threshold convention as
    ``lgc.lgc_layers`` (bottom sentinel = (K+1)-th magnitude)."""
    u = u.astype(jnp.float32)
    d = u.shape[0]
    ktot = int(sum(ks))
    mags = jnp.abs(u)
    top_vals = -jnp.sort(-mags)
    cum = []
    acc = 0
    for k in ks:
        acc += int(k)
        cum.append(acc - 1)
    inner = top_vals[jnp.asarray(cum[:-1])] if len(ks) > 1 else jnp.zeros((0,), jnp.float32)
    bottom = top_vals[ktot] if ktot < d else jnp.float32(-1.0)
    thr = jnp.concatenate(
        [jnp.full((1,), jnp.inf, jnp.float32), inner, bottom.reshape((1,))]
    )
    layers = [band_sparsify_ref(u, thr[c], thr[c + 1]) for c in range(len(ks))]
    return jnp.stack(layers), thr


def lgc_decode_ref(layers: jax.Array) -> jax.Array:
    """Server-side decode: LGC_k(u) = sum of the received layers (Eq. 2)."""
    return jnp.sum(layers, axis=0)
