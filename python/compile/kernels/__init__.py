"""L1 Pallas kernels (lgc) and their pure-jnp oracles (ref)."""
from . import lgc, ref  # noqa: F401
