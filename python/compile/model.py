"""L2 — the paper's training workloads as JAX graphs with a flat-param ABI.

Three models, matching Sec. 4.1 of the paper:

- ``lr``  — logistic regression, 784 -> 10 softmax         (MNIST-class data)
- ``cnn`` — 2x(conv3x3 + relu + maxpool2) -> fc128 -> 10   (MNIST-class data)
- ``rnn`` — char-level GRU, vocab 64, embed 32, hidden 128 (Shakespeare)

Every graph works on a single flat ``f32[P]`` parameter vector owned by the
Rust coordinator; (un)flattening happens inside the jitted function so the
PJRT ABI is a handful of dense buffers.  The fused local SGD update is the
L1 Pallas ``sgd_step`` kernel, so the Pallas kernel lowers into the same HLO
the Rust runtime executes on every local step (Alg. 1 line 6).

Exported graphs per model (lowered by ``aot.py``):

- ``local``: (params, x, y, lr) -> (params', loss)   one local SGD step
- ``grad`` : (params, x, y)     -> (grads,  loss)    raw gradient (tests, FedAvg)
- ``eval`` : (params, x, y)     -> (loss_sum, correct_count)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lgc

# ---------------------------------------------------------------------------
# Shapes / hyperparameters (paper Sec. 4.1: batch 64, lr 0.01)
# ---------------------------------------------------------------------------

BATCH = 64
IMG = 784          # 28 * 28
NCLASS = 10
VOCAB = 64         # char vocab for the Shakespeare corpus (Rust maps chars)
EMBED = 32
HIDDEN = 128
SEQ = 24           # positions per example; artifact input is SEQ + 1 chars


@dataclass(frozen=True)
class Spec:
    """Static description of one model's flat-parameter layout."""

    name: str
    shapes: tuple[tuple[str, tuple[int, ...]], ...]
    x_shape: tuple[int, ...] = ()
    x_dtype: str = "f32"

    @property
    def sizes(self) -> list[int]:
        return [int(np.prod(s)) for _, s in self.shapes]

    @property
    def nparams(self) -> int:
        return sum(self.sizes)

    def unflatten(self, flat: jax.Array) -> dict[str, jax.Array]:
        out = {}
        off = 0
        for (name, shape), size in zip(self.shapes, self.sizes):
            out[name] = jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)
            off += size
        return out


LR_SPEC = Spec(
    "lr",
    (("w", (IMG, NCLASS)), ("b", (NCLASS,))),
    x_shape=(BATCH, IMG),
)

CNN_SPEC = Spec(
    "cnn",
    (
        ("c1w", (3, 3, 1, 16)), ("c1b", (16,)),
        ("c2w", (3, 3, 16, 32)), ("c2b", (32,)),
        ("f1w", (7 * 7 * 32, 128)), ("f1b", (128,)),
        ("f2w", (128, NCLASS)), ("f2b", (NCLASS,)),
    ),
    x_shape=(BATCH, IMG),
)

RNN_SPEC = Spec(
    "rnn",
    (
        ("emb", (VOCAB, EMBED)),
        ("wz", (EMBED + HIDDEN, HIDDEN)), ("bz", (HIDDEN,)),
        ("wr", (EMBED + HIDDEN, HIDDEN)), ("br", (HIDDEN,)),
        ("wh", (EMBED + HIDDEN, HIDDEN)), ("bh", (HIDDEN,)),
        ("wo", (HIDDEN, VOCAB)), ("bo", (VOCAB,)),
    ),
    x_shape=(BATCH, SEQ + 1),
    x_dtype="i32",
)

SPECS = {"lr": LR_SPEC, "cnn": CNN_SPEC, "rnn": RNN_SPEC}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy. logits [N, C], labels int32 [N]."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[:, None].astype(jnp.int32), axis=-1)
    return -jnp.mean(ll)


def lr_logits(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def cnn_logits(p: dict, x: jax.Array) -> jax.Array:
    img = x.reshape(-1, 28, 28, 1)
    z = jax.lax.conv_general_dilated(
        img, p["c1w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + p["c1b"]
    z = jax.nn.relu(z)
    z = jax.lax.reduce_window(z, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    z = jax.lax.conv_general_dilated(
        z, p["c2w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + p["c2b"]
    z = jax.nn.relu(z)
    z = jax.lax.reduce_window(z, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    z = z.reshape(z.shape[0], -1)
    z = jax.nn.relu(z @ p["f1w"] + p["f1b"])
    return z @ p["f2w"] + p["f2b"]


def _gru_cell(p: dict, h: jax.Array, e: jax.Array) -> jax.Array:
    xh = jnp.concatenate([e, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xrh = jnp.concatenate([e, r * h], axis=-1)
    hbar = jnp.tanh(xrh @ p["wh"] + p["bh"])
    return (1.0 - z) * h + z * hbar


def rnn_logits(p: dict, x: jax.Array) -> jax.Array:
    """x int32 [B, SEQ+1]; returns logits [B, SEQ, VOCAB] for next-char."""
    emb = p["emb"][x]  # [B, SEQ+1, EMBED]
    h = jnp.zeros((x.shape[0], HIDDEN), jnp.float32)
    outs = []
    for t in range(SEQ):
        h = _gru_cell(p, h, emb[:, t, :])
        outs.append(h @ p["wo"] + p["bo"])
    return jnp.stack(outs, axis=1)


def model_loss(name: str, flat: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    spec = SPECS[name]
    p = spec.unflatten(flat)
    if name == "lr":
        return _xent(lr_logits(p, x), y)
    if name == "cnn":
        return _xent(cnn_logits(p, x), y)
    if name == "rnn":
        logits = rnn_logits(p, x)  # targets are x shifted by one
        tgt = x[:, 1:].reshape(-1)
        return _xent(logits.reshape(-1, VOCAB), tgt)
    raise ValueError(name)


def model_logits_labels(name: str, flat: jax.Array, x: jax.Array, y: jax.Array):
    spec = SPECS[name]
    p = spec.unflatten(flat)
    if name == "lr":
        return lr_logits(p, x), y
    if name == "cnn":
        return cnn_logits(p, x), y
    if name == "rnn":
        return rnn_logits(p, x).reshape(-1, VOCAB), x[:, 1:].reshape(-1)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Exported graphs
# ---------------------------------------------------------------------------


def local_step(name: str):
    """(params, x, y, lr) -> (params', loss): one local SGD step, with the
    parameter update fused through the L1 Pallas kernel."""

    def fn(flat, x, y, lr):
        loss, grads = jax.value_and_grad(lambda f: model_loss(name, f, x, y))(flat)
        new = lgc.sgd_step(flat, grads, lr)
        return (new, loss)

    return fn


def grad_graph(name: str):
    """(params, x, y) -> (grads, loss)."""

    def fn(flat, x, y):
        loss, grads = jax.value_and_grad(lambda f: model_loss(name, f, x, y))(flat)
        return (grads, loss)

    return fn


def eval_graph(name: str):
    """(params, x, y) -> (loss_sum, correct_count) over one batch, both f32.
    loss_sum = mean-loss * positions so Rust can aggregate exactly."""

    def fn(flat, x, y):
        logits, labels = model_logits_labels(name, flat, x, y)
        loss = _xent(logits, labels)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32))
        n = jnp.float32(logits.shape[0])
        return (loss * n, correct)

    return fn


def lgc_compress_graph(d: int, ks: tuple[int, ...]):
    """(u f32[d]) -> (layers f32[C,d], thr f32[C+1]): the full LGC_k encoder
    (global top-k select in XLA + Pallas band kernels), exported for the
    artifact-compression ablation (DESIGN.md A2)."""

    def fn(u):
        layers, thr = lgc.lgc_layers(u, ks)
        return (layers, thr)

    return fn


# ---------------------------------------------------------------------------
# Initialization (build-time; exported to artifacts/<model>_init.bin)
# ---------------------------------------------------------------------------


def init_params(name: str, seed: int = 42) -> np.ndarray:
    """He-style init, deterministic; returned as a flat f32 numpy vector."""
    spec = SPECS[name]
    key = jax.random.PRNGKey(seed)
    chunks = []
    for pname, shape in spec.shapes:
        key, sub = jax.random.split(key)
        if pname.endswith("b") and len(shape) == 1:
            chunks.append(np.zeros(shape, np.float32).ravel())
        else:
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            scale = np.sqrt(2.0 / max(fan_in, 1)).astype(np.float32)
            w = jax.random.normal(sub, shape, jnp.float32) * scale
            chunks.append(np.asarray(w, np.float32).ravel())
    return np.concatenate(chunks)


def example_args(name: str, graph: str):
    """ShapeDtypeStructs for lowering the given graph of the given model."""
    spec = SPECS[name]
    p = jax.ShapeDtypeStruct((spec.nparams,), jnp.float32)
    xd = jnp.int32 if spec.x_dtype == "i32" else jnp.float32
    x = jax.ShapeDtypeStruct(spec.x_shape, xd)
    # y is ignored by the rnn graphs but kept in the ABI for uniformity.
    y = jax.ShapeDtypeStruct((BATCH,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    if graph == "local":
        return (p, x, y, lr)
    if graph in ("grad", "eval"):
        return (p, x, y)
    raise ValueError(graph)
