"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lgc, ref

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def _rand(n, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


# ---------------------------------------------------------------------------
# band_sparsify
# ---------------------------------------------------------------------------


@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    hi=st.floats(min_value=0.0, max_value=4.0),
    width=st.floats(min_value=0.0, max_value=2.0),
)
def test_band_sparsify_matches_ref(n, seed, hi, width):
    x = _rand(n, seed)
    lo = max(hi - width, 0.0)
    out = lgc.band_sparsify(x, hi, lo)
    exp = ref.band_sparsify_ref(x, hi, lo)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_band_sparsify_keeps_band_only():
    x = jnp.asarray([0.1, -0.5, 2.0, -3.0, 0.9])
    out = np.asarray(lgc.band_sparsify(x, 2.0, 0.5))
    np.testing.assert_array_equal(out, np.asarray([0.0, 0.0, 2.0, 0.0, 0.9], np.float32))


def test_band_sparsify_inf_top_keeps_everything_above_lo():
    x = _rand(3000, 7)
    out = np.asarray(lgc.band_sparsify(x, np.inf, 0.0))
    exp = np.where(np.abs(np.asarray(x)) > 0.0, np.asarray(x), 0.0)
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize("n", [1, 1023, 1024, 1025, 4096, 10000])
def test_band_sparsify_padding_boundaries(n):
    """Exercise the tile-padding wrapper at and around TILE multiples."""
    x = _rand(n, n)
    out = lgc.band_sparsify(x, 1.0, 0.3)
    exp = ref.band_sparsify_ref(x, 1.0, 0.3)
    assert out.shape == (n,)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_band_sparsify_dtypes(dtype):
    x = _rand(512, 3).astype(dtype)
    out = lgc.band_sparsify(x, 1.0, 0.2)
    exp = ref.band_sparsify_ref(x, 1.0, 0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=0)


# ---------------------------------------------------------------------------
# ef_update / sgd_step
# ---------------------------------------------------------------------------


@given(
    n=st.integers(min_value=1, max_value=8000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ef_update_matches_ref(n, seed):
    u = _rand(n, seed)
    g = _rand(n, seed + 1)
    np.testing.assert_array_equal(
        np.asarray(lgc.ef_update(u, g)), np.asarray(ref.ef_update_ref(u, g))
    )


@given(
    n=st.integers(min_value=1, max_value=8000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    lr=st.floats(min_value=1e-4, max_value=1.0),
)
def test_sgd_step_matches_ref(n, seed, lr):
    p = _rand(n, seed)
    g = _rand(n, seed + 1)
    np.testing.assert_allclose(
        np.asarray(lgc.sgd_step(p, g, lr)),
        np.asarray(ref.sgd_step_ref(p, g, lr)),
        rtol=1e-6, atol=1e-7,
    )


def test_ef_update_telescopes():
    """Alg. 1 line 11: the memory absorbs exactly what compression dropped."""
    u = _rand(4096, 11)
    layers, _ = lgc.lgc_layers(u, (40, 160, 600))
    g = jnp.sum(layers, axis=0)
    e = lgc.ef_update(u, g)
    np.testing.assert_allclose(np.asarray(e + g), np.asarray(u), atol=1e-6)


# ---------------------------------------------------------------------------
# lgc_layers (LGC_k encoder, Eq. 2)
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=16, max_value=4096),
    fracs=st.lists(
        st.floats(min_value=0.01, max_value=0.3), min_size=1, max_size=4
    ),
)
def test_lgc_layers_matches_ref(seed, n, fracs):
    u = _rand(n, seed)
    ks = tuple(max(1, int(f * n)) for f in fracs)
    if sum(ks) > n:
        ks = (max(1, n // (2 * len(ks))),) * len(ks)
    layers, thr = lgc.lgc_layers(u, ks)
    layers_r, thr_r = ref.lgc_layers_ref(u, ks)
    np.testing.assert_array_equal(np.asarray(layers), np.asarray(layers_r))
    np.testing.assert_array_equal(np.asarray(thr), np.asarray(thr_r))


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=32, max_value=4096),
)
def test_lgc_layers_partition_invariant(seed, n):
    """Layers are pairwise disjoint and their union is the top-K support."""
    u = _rand(n, seed)
    ks = (max(1, n // 32), max(1, n // 16), max(1, n // 8))
    layers, _ = lgc.lgc_layers(u, ks)
    L = np.asarray(layers)
    supports = L != 0.0
    # pairwise disjoint
    assert (supports.sum(axis=0) <= 1).all()
    # union size == sum(ks) when magnitudes are distinct (generic case)
    mags = np.abs(np.asarray(u))
    if len(np.unique(mags)) == n:
        assert supports.any(axis=0).sum() == sum(ks)
        # union support == the sum(ks) largest |u|
        dec = L.sum(axis=0)
        topk_exp = np.asarray(ref.topk_ref(u, sum(ks)))
        np.testing.assert_array_equal(dec, topk_exp)


def test_lgc_layers_ordered_by_magnitude():
    """Every element of layer c dominates every element of layer c+1."""
    u = _rand(2048, 5)
    layers, _ = lgc.lgc_layers(u, (20, 80, 300))
    L = np.abs(np.asarray(layers))
    for c in range(L.shape[0] - 1):
        lo_c = L[c][L[c] > 0].min()
        hi_next = L[c + 1].max()
        assert lo_c >= hi_next


def test_lgc_layers_k_equals_d():
    u = _rand(1024, 9)
    layers, _ = lgc.lgc_layers(u, (512, 512))
    dec = np.asarray(layers).sum(axis=0)
    np.testing.assert_allclose(dec, np.asarray(u), atol=0)


def test_lgc_layers_contraction():
    """gamma-contraction: ||u - LGC_k(u)||^2 <= (1 - K/D) ||u||^2."""
    for seed in range(5):
        u = _rand(4096, seed)
        ks = (40, 160, 600)
        layers, _ = lgc.lgc_layers(u, ks)
        res = np.asarray(u - jnp.sum(layers, axis=0))
        lhs = (res ** 2).sum()
        rhs = (1 - sum(ks) / 4096) * (np.asarray(u) ** 2).sum()
        assert lhs <= rhs * (1 + 1e-6)


def test_lgc_layers_rejects_bad_k():
    with pytest.raises(ValueError):
        lgc.lgc_layers(_rand(64, 0), (65,))
