"""L2 model correctness: shapes, gradients vs finite differences, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(name, seed=0):
    key = jax.random.PRNGKey(seed)
    spec = M.SPECS[name]
    if spec.x_dtype == "i32":
        x = jax.random.randint(key, spec.x_shape, 0, M.VOCAB, dtype=jnp.int32)
    else:
        x = jax.random.uniform(key, spec.x_shape, jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (M.BATCH,), 0, M.NCLASS,
                           dtype=jnp.int32)
    return x, y


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_param_counts(name):
    flat = M.init_params(name)
    assert flat.shape == (M.SPECS[name].nparams,)
    assert np.isfinite(flat).all()


def test_expected_sizes():
    assert M.LR_SPEC.nparams == 7850
    assert M.CNN_SPEC.nparams == 206922
    assert M.RNN_SPEC.nparams == 72128


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_loss_finite_and_near_uniform_at_init(name):
    flat = jnp.asarray(M.init_params(name))
    x, y = _batch(name)
    loss = M.model_loss(name, flat, x, y)
    assert np.isfinite(float(loss))
    # ~ log(nclass) for random labels at (near-)random init
    nc = M.NCLASS if name != "rnn" else M.VOCAB
    assert float(loss) < np.log(nc) * 3


@pytest.mark.parametrize("name", ["lr", "rnn"])
def test_grad_matches_finite_differences(name):
    flat = jnp.asarray(M.init_params(name)) * 0.1
    x, y = _batch(name)
    grads, loss = M.grad_graph(name)(flat, x, y)
    grads = np.asarray(grads)
    rng = np.random.default_rng(0)
    idxs = rng.choice(flat.shape[0], size=8, replace=False)
    eps = 1e-3
    for i in idxs:
        fp = np.asarray(flat).copy(); fp[i] += eps
        fm = np.asarray(flat).copy(); fm[i] -= eps
        lp = float(M.model_loss(name, jnp.asarray(fp), x, y))
        lm = float(M.model_loss(name, jnp.asarray(fm), x, y))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - grads[i]) < 5e-3 + 0.05 * abs(fd), (i, fd, grads[i])


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_local_step_decreases_loss(name):
    flat = jnp.asarray(M.init_params(name))
    x, y = _batch(name)
    step = M.local_step(name)
    loss0 = None
    cur = flat
    for _ in range(8):
        cur, loss = step(cur, x, y, jnp.float32(0.05))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_local_step_equals_grad_plus_sgd(name):
    """local == grad + pallas sgd_step composition (ABI consistency)."""
    flat = jnp.asarray(M.init_params(name))
    x, y = _batch(name, 3)
    lr = jnp.float32(0.01)
    p1, l1 = M.local_step(name)(flat, x, y, lr)
    g, l2 = M.grad_graph(name)(flat, x, y)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(p1), np.asarray(flat - lr * g), rtol=1e-5, atol=1e-7
    )


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_eval_graph_counts(name):
    flat = jnp.asarray(M.init_params(name))
    x, y = _batch(name, 9)
    loss_sum, correct = M.eval_graph(name)(flat, x, y)
    npos = M.BATCH if name != "rnn" else M.BATCH * M.SEQ
    assert 0.0 <= float(correct) <= npos
    assert float(loss_sum) > 0.0


def test_lr_learns_separable_problem():
    """End-to-end sanity: LR reaches high train accuracy on separable data."""
    rng = np.random.default_rng(0)
    protos = rng.normal(size=(M.NCLASS, M.IMG)).astype(np.float32)
    y = rng.integers(0, M.NCLASS, size=M.BATCH).astype(np.int32)
    x = protos[y] + 0.05 * rng.normal(size=(M.BATCH, M.IMG)).astype(np.float32)
    flat = jnp.zeros((M.LR_SPEC.nparams,), jnp.float32)
    step = jax.jit(M.local_step("lr"))
    for _ in range(60):
        flat, loss = step(flat, jnp.asarray(x), jnp.asarray(y), jnp.float32(0.05))
    _, correct = M.eval_graph("lr")(flat, jnp.asarray(x), jnp.asarray(y))
    assert float(correct) / M.BATCH > 0.95
