"""AOT pipeline: lowered HLO text is parseable, stable, and loadable."""

import os

import numpy as np
import pytest

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_emitted_for_lr():
    text = aot.lower_model_graph("lr", "grad")
    assert "HloModule" in text
    assert "ENTRY" in text
    # flat-params ABI: first arg is f32[7850]
    assert "f32[7850]" in text


def test_hlo_text_no_custom_calls():
    """interpret=True pallas must lower to plain HLO (no Mosaic custom-call
    survives); otherwise the CPU PJRT client cannot run the artifact."""
    for graph in ("local", "grad", "eval"):
        text = aot.lower_model_graph("lr", graph)
        assert "custom-call" not in text, graph


def test_compress_artifact_shape():
    text = aot.lower_compress(2048, (16, 64, 256))
    assert "HloModule" in text
    assert "f32[3,2048]" in text  # layers output


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_init_bins_match_specs(name):
    path = os.path.join(ARTIFACTS, f"{name}_init.bin")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    flat = np.fromfile(path, dtype=np.float32)
    assert flat.shape == (M.SPECS[name].nparams,)
    # deterministic: regenerating gives bit-identical params
    np.testing.assert_array_equal(flat, M.init_params(name))


def test_manifest_contents(tmp_path):
    path = tmp_path / "manifest.toml"
    aot.write_manifest(str(path))
    text = path.read_text()
    assert "[lr]\nparams = 7850" in text
    assert "[cnn]\nparams = 206922" in text
    assert "[rnn]\nparams = 72128" in text
    assert f"compress_d = {aot.COMPRESS_D}" in text


@pytest.mark.parametrize("name", ["lr", "cnn", "rnn"])
def test_artifacts_exist_after_make(name):
    for graph in ("local", "grad", "eval"):
        path = os.path.join(ARTIFACTS, f"{name}_{graph}.hlo.txt")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        head = open(path).read(200)
        assert "HloModule" in head
