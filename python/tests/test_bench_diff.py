"""Fixture-driven tests for bench_diff.py — tolerance bands, the
zero-baseline guard, and the null-bless/--update bootstrap path.

Stdlib-only (no jax/pytest required): runs under pytest or directly via
``python3 python/tests/test_bench_diff.py``.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import bench_diff  # noqa: E402


def rec(bench, case, value, unit):
    return {"bench": bench, "case": case, "value": value, "unit": unit}


class CompareTolerances(unittest.TestCase):
    def test_throughput_band_fails_only_on_regression(self):
        base = [rec("b", "c", 100.0, "events/s")]
        ok, _ = bench_diff.compare(base, [rec("b", "c", 41.0, "events/s")], 0.6)
        self.assertEqual(ok, [])
        fail, _ = bench_diff.compare(base, [rec("b", "c", 39.0, "events/s")], 0.6)
        self.assertEqual(len(fail), 1)
        # Speedups never fail.
        ok, _ = bench_diff.compare(base, [rec("b", "c", 900.0, "events/s")], 0.6)
        self.assertEqual(ok, [])

    def test_memory_band_fails_only_on_growth(self):
        base = [rec("b", "c", 100.0, "mb")]
        ok, _ = bench_diff.compare(base, [rec("b", "c", 10.0, "mb")], 0.6)
        self.assertEqual(ok, [])
        fail, _ = bench_diff.compare(base, [rec("b", "c", 161.0, "mb")], 0.6)
        self.assertEqual(len(fail), 1)

    def test_exact_and_sim_units(self):
        base = [rec("b", "n", 7, "count"), rec("b", "t", 1.5, "sim_s")]
        cur = [rec("b", "n", 7, "count"), rec("b", "t", 1.5 + 1e-9, "sim_s")]
        ok, _ = bench_diff.compare(base, cur, 0.6)
        self.assertEqual(ok, [])
        fail, _ = bench_diff.compare(base, [rec("b", "n", 8, "count")], 0.6)
        self.assertEqual(len(fail), 1)


class ZeroBaselineGuard(unittest.TestCase):
    """A legitimately-zero baseline must neither crash, silently pass a
    regression (throughput floor 0), nor fail with a misleading band
    message (memory ceiling 0)."""

    def test_zero_throughput_baseline_flags_positive_current(self):
        base = [rec("b", "c", 0.0, "events/s")]
        fail, _ = bench_diff.compare(base, [rec("b", "c", 50.0, "events/s")], 0.6)
        self.assertEqual(len(fail), 1)
        self.assertIn("zero baseline", fail[0])

    def test_zero_memory_baseline_gets_zero_message_not_band(self):
        base = [rec("b", "c", 0.0, "mb")]
        fail, _ = bench_diff.compare(base, [rec("b", "c", 3.0, "mb")], 0.6)
        self.assertEqual(len(fail), 1)
        self.assertIn("zero baseline", fail[0])
        self.assertNotIn("band +", fail[0])

    def test_zero_stays_zero_passes(self):
        base = [rec("b", "c", 0.0, "rounds/s")]
        ok, _ = bench_diff.compare(base, [rec("b", "c", 0.0, "rounds/s")], 0.6)
        self.assertEqual(ok, [])

    def test_near_zero_baseline_counts_as_zero(self):
        base = [rec("b", "c", 1e-12, "events/s")]
        fail, _ = bench_diff.compare(base, [rec("b", "c", 50.0, "events/s")], 0.6)
        self.assertEqual(len(fail), 1)
        self.assertIn("zero baseline", fail[0])

    def test_exact_units_unaffected_by_guard(self):
        # A zero count baseline stays an exact comparison.
        base = [rec("b", "c", 0, "count")]
        fail, _ = bench_diff.compare(base, [rec("b", "c", 1, "count")], 0.6)
        self.assertEqual(len(fail), 1)
        self.assertIn("exact", fail[0])


class BlessAndUpdate(unittest.TestCase):
    def test_null_and_missing_baseline_entries_bless(self):
        base = [rec("b", "old", None, "events/s")]
        cur = [rec("b", "old", 10.0, "events/s"), rec("b", "new", 5.0, "events/s")]
        fail, blessed = bench_diff.compare(base, cur, 0.6)
        self.assertEqual(fail, [])
        self.assertEqual(len(blessed), 2)

    def test_main_update_merges_blessed_baseline(self):
        with tempfile.TemporaryDirectory() as d:
            baseline = os.path.join(d, "BENCH_BASELINE.json")
            current = os.path.join(d, "BENCH_x.json")
            with open(baseline, "w") as f:
                json.dump(
                    [rec("b", "keep", 1, "count"), rec("b", "fill", None, "sim_s")],
                    f,
                )
            with open(current, "w") as f:
                json.dump([rec("b", "fill", 2.5, "sim_s")], f)
            code = bench_diff.main([current, "--baseline", baseline, "--update"])
            self.assertEqual(code, 0)
            with open(baseline) as f:
                merged = {bench_diff.key(r): r["value"] for r in json.load(f)}
            self.assertEqual(merged[("b", "keep")], 1)
            self.assertEqual(merged[("b", "fill")], 2.5)

    def test_main_zero_baseline_exits_nonzero(self):
        with tempfile.TemporaryDirectory() as d:
            baseline = os.path.join(d, "BENCH_BASELINE.json")
            current = os.path.join(d, "BENCH_x.json")
            with open(baseline, "w") as f:
                json.dump([rec("b", "c", 0.0, "events/s")], f)
            with open(current, "w") as f:
                json.dump([rec("b", "c", 50.0, "events/s")], f)
            self.assertEqual(bench_diff.main([current, "--baseline", baseline]), 1)


if __name__ == "__main__":
    unittest.main()
