#!/usr/bin/env python3
"""Diff bench JSON records against the committed baseline.

The bench binaries (``cargo bench --bench bench_scenario -- --json`` etc.)
write ``BENCH_<name>.json`` files of ``{bench, case, value, unit}`` records.
This script compares them against ``BENCH_BASELINE.json`` (same schema) and
exits nonzero on an out-of-tolerance regression, so CI pins the bench
trajectory alongside the golden traces.

Tolerance policy by unit:

* ``count`` / ``bytes`` — deterministic simulation counters: must match the
  baseline exactly.
* ``sim_s`` / ``sim`` — deterministic simulated quantities (simulated
  seconds, eval accuracy, rewards): 1e-6 relative (float printing).
* ``mb`` — peak memory (RSS high-water): banded like throughput but in the
  *opposite* direction — only an increase above the band fails (an
  O(population) leak shows up as a blowup here; shrinking is always fine).
* anything else (``events/s``, ``rounds/s``, ``gib/s``, wall times) —
  host-dependent throughput: banded at +-RELATIVE_BAND (default 0.60; CI
  runners are noisy), failing only on *regressions* below the band.
  Speedups never fail.

Relative bands are meaningless against a (near-)zero baseline: a zero
throughput baseline would make the floor 0 and silently wave any
regression through, and a zero memory baseline would fail every positive
measurement with a misleading band message. Baselines with
``|value| <= ZERO_EPS`` therefore take an explicit absolute branch: the
current value must also be (near-)zero, anything else fails with a
``zero baseline`` message telling you to re-bless (set the baseline entry
to ``null``) or ``--update``.

Bless convention (bootstrap): a baseline entry whose value is ``null`` (or
a record with no baseline entry at all) is blessed from the current run
instead of compared. With ``--update`` the merged baseline is written back;
regenerate locally and commit it after an intentional perf change:

    cargo bench --bench bench_scenario -- --json
    cargo bench --bench bench_population_scale -- --json
    cargo bench --bench bench_edge -- --json
    python3 python/bench_diff.py --update BENCH_*.json
"""

from __future__ import annotations

import argparse
import json
import sys

RELATIVE_BAND = 0.60
EXACT_UNITS = {"count", "bytes"}
SIM_UNITS = {"sim_s", "sim"}
# Peak-memory units: regressions are *increases*, not drops.
MEM_UNITS = {"mb"}
# Below this magnitude a baseline is "zero" and relative bands don't apply.
ZERO_EPS = 1e-9


def key(rec):
    return (rec["bench"], rec["case"])


def load_records(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise SystemExit(f"{path}: expected a JSON array of records")
    for rec in data:
        for field in ("bench", "case", "unit"):
            if field not in rec:
                raise SystemExit(f"{path}: record missing `{field}`: {rec}")
    return data


def compare(baseline, current, band):
    """Return (failures, blessed) comparing current records to baseline."""
    failures, blessed = [], []
    by_key = {key(r): r for r in baseline}
    for rec in current:
        k = rec["bench"], rec["case"]
        base = by_key.get(k)
        if base is None or base.get("value") is None:
            blessed.append(rec)
            by_key[k] = dict(rec)
            continue
        unit, got, want = rec["unit"], rec["value"], base["value"]
        name = f"{k[0]}:{k[1]} [{unit}]"
        if unit in EXACT_UNITS:
            if got != want:
                failures.append(f"{name}: {got} != baseline {want} (exact)")
        elif unit in SIM_UNITS:
            if abs(got - want) > 1e-6 * max(1.0, abs(want)):
                failures.append(f"{name}: {got} != baseline {want} (sim-exact)")
        elif abs(want) <= ZERO_EPS:
            # Banded units against a zero baseline: the band is degenerate
            # (floor/ceiling of 0), so require zero-stays-zero explicitly.
            if abs(got) > ZERO_EPS:
                failures.append(
                    f"{name}: {got} vs zero baseline — relative band "
                    f"undefined; re-bless (null the baseline entry) or "
                    f"run with --update"
                )
        elif unit in MEM_UNITS:
            # Memory: only growth above the band is a regression.
            ceiling = want * (1.0 + band)
            if got > ceiling:
                failures.append(
                    f"{name}: {got:.2f} > {ceiling:.2f} "
                    f"(baseline {want:.2f}, band +{band:.0%})"
                )
        else:
            # Throughput-style: only a drop below the band is a regression.
            floor = want * (1.0 - band)
            if got < floor:
                failures.append(
                    f"{name}: {got:.2f} < {floor:.2f} "
                    f"(baseline {want:.2f}, band -{band:.0%})"
                )
    return failures, blessed


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="BENCH_*.json record files")
    ap.add_argument("--baseline", default="BENCH_BASELINE.json")
    ap.add_argument(
        "--band", type=float, default=RELATIVE_BAND,
        help="relative tolerance for throughput units (default %(default)s)",
    )
    ap.add_argument(
        "--update", action="store_true",
        help="write the merged (blessed) baseline back to --baseline",
    )
    args = ap.parse_args(argv)

    try:
        baseline = load_records(args.baseline)
    except FileNotFoundError:
        print(f"note: no baseline at {args.baseline}; blessing everything")
        baseline = []

    current = []
    for path in args.files:
        if path == args.baseline:
            continue
        current.extend(load_records(path))
    if not current:
        raise SystemExit("no bench records to compare")

    failures, blessed = compare(baseline, current, args.band)

    for rec in blessed:
        print(f"bless: {rec['bench']}:{rec['case']} = "
              f"{rec['value']} [{rec['unit']}]")
    if args.update:
        # Refresh every measured key (intentional change), keep stale ones.
        by_key = {key(r): r for r in baseline}
        by_key.update({key(r): dict(r) for r in current})
        merged = sorted(by_key.values(), key=key)
        with open(args.baseline, "w") as f:
            json.dump(merged, f, indent=2)
            f.write("\n")
        print(f"wrote {args.baseline} ({len(merged)} records)")

    if failures:
        print(f"\n{len(failures)} bench regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  FAIL {msg}", file=sys.stderr)
        return 1
    compared = len(current) - len(blessed)
    print(f"ok: {compared} compared, {len(blessed)} blessed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
