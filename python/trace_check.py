#!/usr/bin/env python3
"""Schema validator for the lgc JSONL event trace (and its Chrome export).

Stdlib-only, mirroring the Rust side from the outside: the recorder
(`rust/src/obs/mod.rs`) serializes flat JSON objects with a fixed key
vocabulary, and this script re-checks every line independently so format
drift on either side fails CI.

Usage:
    python3 python/trace_check.py trace.jsonl [more.jsonl ...]
    python3 python/trace_check.py --chrome chrome_trace.json

Checks (JSONL mode):
  - every non-empty line is a flat JSON object (no nesting)
  - `t` present, finite, >= 0; `kind` present and in the known vocabulary
  - integer keys (round/client/zone/layer/channel/bytes) are ints >= 0
  - span keys (`dur`) and attribution components are finite and >= 0
  - `round` records: compute+uplink+backhaul+downlink+wait == dur (1e-6)
    and `bound` names a component (or is empty for a zero-duration round)
  - round records appear in increasing round order

Deliberately NOT checked: global monotonicity of `t`. Span records are
emitted at scheduling time with a future-dated arrival `t`, so the trace
interleaves by causal order, not timestamp order.
"""

import json
import math
import sys

KINDS = {
    "compute_start",
    "compute_done",
    "uplink_arrive",
    "uplink_drop",
    "backhaul_enqueue",
    "backhaul_arrive",
    "edge_fold",
    "downlink_arrive",
    "sync_confirm",
    "aggregate",
    "handoff",
    "migrate",
    "churn_drop",
    "client_offline",
    "round",
}

INT_KEYS = ("round", "client", "zone", "layer", "channel", "bytes")
FLOAT_KEYS = ("dur", "compute", "uplink", "backhaul", "downlink", "wait")
COMPONENTS = ("compute", "uplink", "backhaul", "downlink", "wait")
BOUND_LABELS = set(COMPONENTS) | {""}
KNOWN_KEYS = {"t", "kind", "bound", "crit_client", "crit_channel"} | set(
    INT_KEYS
) | set(FLOAT_KEYS)


def fail(path, lineno, msg):
    raise SystemExit(f"{path}:{lineno}: {msg}")


def check_trace(path):
    n_records = 0
    n_rounds = 0
    last_round = -1
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                fail(path, lineno, f"not JSON: {e}")
            if not isinstance(rec, dict):
                fail(path, lineno, "line is not a JSON object")
            for key, value in rec.items():
                if key not in KNOWN_KEYS:
                    fail(path, lineno, f"unknown key `{key}`")
                if isinstance(value, (dict, list)):
                    fail(path, lineno, f"nested value under `{key}`")
            t = rec.get("t")
            if not isinstance(t, (int, float)) or not math.isfinite(t) or t < 0:
                fail(path, lineno, f"bad t: {t!r}")
            kind = rec.get("kind")
            if kind not in KINDS:
                fail(path, lineno, f"unknown kind: {kind!r}")
            for key in INT_KEYS:
                if key in rec:
                    v = rec[key]
                    if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                        fail(path, lineno, f"bad {key}: {v!r}")
            for key in FLOAT_KEYS:
                if key in rec:
                    v = rec[key]
                    if (
                        not isinstance(v, (int, float))
                        or not math.isfinite(v)
                        or v < 0
                    ):
                        fail(path, lineno, f"bad {key}: {v!r}")
            for key in ("crit_client", "crit_channel"):
                if key in rec:
                    v = rec[key]
                    if not isinstance(v, int) or isinstance(v, bool) or v < -1:
                        fail(path, lineno, f"bad {key}: {v!r}")
            if kind == "round":
                for key in ("round", "dur", *COMPONENTS, "bound"):
                    if key not in rec:
                        fail(path, lineno, f"round record missing `{key}`")
                if rec["bound"] not in BOUND_LABELS:
                    fail(path, lineno, f"bad bound: {rec['bound']!r}")
                total = sum(rec[c] for c in COMPONENTS)
                if abs(total - rec["dur"]) > 1e-6:
                    fail(
                        path,
                        lineno,
                        f"attribution components sum {total} != dur {rec['dur']}",
                    )
                if rec["round"] <= last_round:
                    fail(
                        path,
                        lineno,
                        f"round {rec['round']} out of order (after {last_round})",
                    )
                last_round = rec["round"]
                n_rounds += 1
            n_records += 1
    if n_records == 0:
        raise SystemExit(f"{path}: empty trace")
    print(f"{path}: OK ({n_records} records, {n_rounds} rounds)")


def check_chrome(path):
    with open(path, encoding="utf-8") as fh:
        try:
            doc = json.load(fh)
        except json.JSONDecodeError as e:
            raise SystemExit(f"{path}: not JSON: {e}")
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise SystemExit(f"{path}: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise SystemExit(f"{path}: traceEvents must be a non-empty array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise SystemExit(f"{path}: traceEvents[{i}] is not an object")
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise SystemExit(f"{path}: traceEvents[{i}] missing `{key}`")
        if ev["ph"] not in ("X", "i"):
            raise SystemExit(f"{path}: traceEvents[{i}] bad ph {ev['ph']!r}")
        if ev["ph"] == "X" and ("dur" not in ev or ev["dur"] < 0):
            raise SystemExit(f"{path}: traceEvents[{i}] X event needs dur >= 0")
        if not math.isfinite(ev["ts"]):
            raise SystemExit(f"{path}: traceEvents[{i}] non-finite ts")
    print(f"{path}: OK ({len(events)} trace events)")


def main(argv):
    args = [a for a in argv if a != "--chrome"]
    chrome = len(args) != len(argv)
    if not args:
        raise SystemExit(__doc__.strip().splitlines()[0])
    for path in args:
        if chrome:
            check_chrome(path)
        else:
            check_trace(path)


if __name__ == "__main__":
    main(sys.argv[1:])
